"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes/dtypes
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.block_spmm import block_spmm_kernel_call
from repro.kernels.flash_attention import flash_attention_call
from repro.kernels.ref import block_spmm_ref, flash_attention_ref


def _random_tasks(rng, na, nb, nc, T):
    """Random tasks satisfying the kernel contract: c sorted AND covering
    every output row (the symbolic phase guarantees both)."""
    T = max(T, nc)
    a = rng.integers(0, na, T)
    b = rng.integers(0, nb, T)
    c = np.sort(np.concatenate([np.arange(nc), rng.integers(0, nc, T - nc)]))
    return a.astype(np.int32), b.astype(np.int32), c.astype(np.int32)


@pytest.mark.parametrize("bs", [8, 16, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_spmm_square(bs, dtype):
    rng = np.random.default_rng(bs)
    na, nb, nc, T = 7, 5, 6, 23
    A = jnp.asarray(rng.standard_normal((na, bs, bs)), dtype)
    B = jnp.asarray(rng.standard_normal((nb, bs, bs)), dtype)
    a, b, c = _random_tasks(rng, na, nb, nc, T)
    out = block_spmm_kernel_call(
        A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), num_out=nc, interpret=True
    )
    ref = block_spmm_ref(A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), nc)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("bm,bk,bn", [(16, 32, 8), (64, 16, 32), (128, 256, 128)])
def test_block_spmm_rectangular(bm, bk, bn):
    rng = np.random.default_rng(0)
    na, nb, nc, T = 4, 4, 3, 11
    A = jnp.asarray(rng.standard_normal((na, bm, bk)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((nb, bk, bn)), jnp.float32)
    a, b, c = _random_tasks(rng, na, nb, nc, T)
    out = block_spmm_kernel_call(
        A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), num_out=nc, interpret=True
    )
    ref = block_spmm_ref(A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), nc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_block_spmm_tiled_large_block():
    # bs 1024 forces multi-tile (tm=tn=tk=512) accumulation paths
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((2, 1024, 1024)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((2, 1024, 1024)), jnp.float32)
    a = jnp.asarray([0, 1, 1], jnp.int32)
    b = jnp.asarray([1, 0, 1], jnp.int32)
    c = jnp.asarray([0, 0, 1], jnp.int32)
    out = block_spmm_kernel_call(A, B, a, b, c, num_out=2, interpret=True)
    ref = block_spmm_ref(A, B, a, b, c, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-2)


def test_block_spmm_trailing_trash_row():
    """Kernel contract: every row in [0, num_out) receives >= 1 task (the
    symbolic phase guarantees it); a trailing padded-task trash row is
    allowed and its content is unspecified — callers slice it off.  Rows
    covered by tasks must match the oracle exactly."""
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.standard_normal((3, 16, 16)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((3, 16, 16)), jnp.float32)
    a = jnp.asarray([0, 1, 2, 2], jnp.int32)
    b = jnp.asarray([0, 1, 0, 1], jnp.int32)
    c = jnp.asarray([0, 1, 1, 2], jnp.int32)  # rows 0..2 covered; row 3 = trash
    out = block_spmm_kernel_call(A, B, a, b, c, num_out=4, interpret=True)
    ref = block_spmm_ref(A, B, a, b, c, 4)
    np.testing.assert_allclose(
        np.asarray(out)[:3], np.asarray(ref)[:3], rtol=1e-5, atol=1e-4
    )


@given(
    T=st.integers(1, 40),
    na=st.integers(1, 8),
    nc=st.integers(1, 8),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_block_spmm_property(T, na, nc, seed):
    rng = np.random.default_rng(seed)
    bs = 8
    A = jnp.asarray(rng.standard_normal((na, bs, bs)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((na, bs, bs)), jnp.float32)
    a, b, c = _random_tasks(rng, na, na, nc, T)
    out = block_spmm_kernel_call(
        A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), num_out=nc, interpret=True
    )
    ref = block_spmm_ref(A, B, jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), nc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hk", [(4, 4), (4, 1), (8, 2)])
def test_flash_attention_vs_ref(causal, hq, hk):
    rng = np.random.default_rng(0)
    B, S, D = 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, hk, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, hk, S, D)), jnp.float32)
    out = flash_attention_call(q, k, v, causal=causal, bq=64, bkv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_window():
    rng = np.random.default_rng(1)
    B, H, S, D = 1, 2, 512, 16
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    out = flash_attention_call(q, k, v, causal=True, window=128, bq=64, bkv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(2)
    B, H, S, D = 1, 2, 128, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    out = flash_attention_call(q, k, v, causal=True, bq=64, bkv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2
    )


def test_flash_attention_decode_suffix():
    # Sq < Sk: suffix-aligned queries (speculative/chunked decode)
    rng = np.random.default_rng(3)
    B, H, D = 1, 2, 32
    q = jnp.asarray(rng.standard_normal((B, H, 64, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, 256, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, 256, D)), jnp.float32)
    out = flash_attention_call(q, k, v, causal=True, bq=64, bkv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MegaBlocks-style variable-size grouped GEMM (dropless MoE via block_spmm)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "sizes", [[5, 11, 0, 16], [32], [1, 1, 1, 29], [8, 8, 8, 8]]
)
def test_grouped_gemm_varsize(sizes):
    from repro.kernels.ops import grouped_gemm_varsize

    rng = np.random.default_rng(sum(sizes))
    T, K, N, G = sum(sizes), 16, 24, len(sizes)
    x = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((G, K, N)), jnp.float32)
    out = grouped_gemm_varsize(x, sizes, w)
    # reference: row-by-row
    starts = np.concatenate([[0], np.cumsum(sizes)])
    ref = np.zeros((T, N), np.float32)
    for g in range(G):
        ref[starts[g] : starts[g + 1]] = np.asarray(x)[starts[g] : starts[g + 1]] @ np.asarray(w)[g]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


@given(
    g1=st.integers(0, 40), g2=st.integers(0, 40), g3=st.integers(1, 40), seed=st.integers(0, 50)
)
@settings(max_examples=15, deadline=None)
def test_grouped_gemm_varsize_property(g1, g2, g3, seed):
    from repro.kernels.ops import grouped_gemm_varsize

    sizes = [g1, g2, g3]
    rng = np.random.default_rng(seed)
    T, K, N = sum(sizes), 8, 8
    x = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, K, N)), jnp.float32)
    out = grouped_gemm_varsize(x, sizes, w, tile_m=8)
    starts = np.concatenate([[0], np.cumsum(sizes)])
    ref = np.zeros((T, N), np.float32)
    for g in range(3):
        ref[starts[g] : starts[g + 1]] = np.asarray(x)[starts[g] : starts[g + 1]] @ np.asarray(w)[g]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)
