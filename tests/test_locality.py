"""Locality ledger + executed-task-graph analytics.

Accounting invariants first (conservation, pruning monotonicity, critical
path dominating every worker's busy time), then the SPMD half in a
4-fake-device subprocess: the ledger is an observer — installing it must
not move a single bit of the math — and the rebalanced run of a skewed
layout must measure strictly better locality than the static one.
"""

import json
import os
import subprocess
import sys
import types

import numpy as np
import pytest

from helpers import random_block_matrix

from repro.core.distributed import _exchange_keep_masks
from repro.core.schedule import (
    make_spgemm_plan,
    plan_byte_provenance,
    plan_worker_bytes,
)
from repro.obs import (
    LOCALITY_ITER_KEYS,
    LocalityLedger,
    analyze_plan,
    ledger_of,
    locality_iteration,
    locality_snapshot,
    locality_table,
    plan_provenance,
    project_seconds,
    whatif_rebalanced,
)

BS = 16


def _plan(nparts=4, exchange="p2p", seed=3, density=0.25, **kw):
    m = random_block_matrix(256, BS, density, seed=seed)
    return make_spgemm_plan(m.coords, m.coords, nparts, BS,
                            exchange=exchange, **kw)


# ---------------------------------------------------------------------------
# static byte provenance: conservation and agreement with plan_worker_bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("exchange", ["p2p", "allgather"])
@pytest.mark.parametrize("nparts", [1, 3, 4])
def test_provenance_conserves(exchange, nparts):
    plan = _plan(nparts=nparts, exchange=exchange)
    prov = plan_byte_provenance(plan)
    assert np.array_equal(prov["local"] + prov["shipped"], prov["referenced"])
    recv, send, _ = plan_worker_bytes(plan)
    assert np.array_equal(prov["wire_recv"], recv)
    assert np.array_equal(prov["wire_send"], send)
    if exchange == "p2p":
        # the planned exchange delivers exactly the distinct remote refs
        assert np.array_equal(prov["shipped"], recv)


def test_provenance_memoized_on_plan():
    plan = _plan()
    assert plan_provenance(plan) is plan_provenance(plan)


def test_skewed_pin_localizes_owner_only():
    m = random_block_matrix(256, BS, 0.25, seed=7)
    skew = np.zeros(m.coords.shape[0], dtype=np.int32)
    plan = make_spgemm_plan(m.coords, m.coords, 4, BS,
                            a_owner=skew, b_owner=skew)
    prov = plan_byte_provenance(plan)
    # non-owners hold nothing: every byte they reference was shipped
    assert np.all(prov["local"][1:] == 0.0)
    assert np.array_equal(prov["shipped"][1:], prov["referenced"][1:])
    # task_local padding is False and local_tasks is its row sum
    assert np.array_equal(prov["task_local"].sum(axis=1), prov["local_tasks"])


# ---------------------------------------------------------------------------
# ledger: conservation, delta schema, pruning, wire precision
# ---------------------------------------------------------------------------


def test_ledger_conserves_and_emits_iter_keys():
    plan = _plan()
    lld = LocalityLedger()
    snap = lld.snapshot()
    out = lld.note_dispatch(plan)
    assert out["local_bytes"] + out["shipped_bytes"] == out["referenced_bytes"]
    fields = lld.delta(snap)
    assert sorted(fields) == sorted(LOCALITY_ITER_KEYS)
    assert 0.0 <= fields["locality_flops"] <= 1.0
    assert 0.0 <= fields["locality_bytes"] <= 1.0
    s = lld.summary()
    assert s["dispatches"] == 1 and s["nparts"] == plan.nparts
    for w in s["per_worker"]:
        assert w["local_bytes"] + w["shipped_bytes"] == w["referenced_bytes"]
    # summary totals == per-worker sums
    assert s["referenced_bytes"] == pytest.approx(
        sum(w["referenced_bytes"] for w in s["per_worker"]))


def test_keep_masks_prune_wire_never_local():
    plan = _plan()
    rng = np.random.default_rng(0)
    keep_task = rng.random(plan.tasks.num_tasks) < 0.1
    a_keeps, b_keeps, _live_a, _live_b, stats = _exchange_keep_masks(
        plan, keep_task)
    assert stats["kept_blocks"] < stats["send_blocks"]

    full = LocalityLedger().note_dispatch(plan)
    pruned = LocalityLedger().note_dispatch(plan, keeps=(a_keeps, b_keeps))
    # pruning shrinks the wire, never the residency split
    assert pruned["wire_recv_bytes"] < full["wire_recv_bytes"]
    assert pruned["wire_send_bytes"] == pruned["wire_recv_bytes"]
    assert pruned["local_bytes"] == full["local_bytes"]
    assert pruned["shipped_bytes"] == full["shipped_bytes"]
    # kept wire is exactly the kept payload blocks
    assert pruned["wire_send_bytes"] == stats["kept_blocks"] * BS * BS * 4


def test_bf16_wire_halves_exactly():
    plan = _plan()
    fp32 = LocalityLedger().note_dispatch(plan)
    bf16 = LocalityLedger().note_dispatch(plan, wire_itemsize=2)
    assert bf16["wire_recv_bytes"] == fp32["wire_recv_bytes"] / 2
    assert bf16["wire_send_bytes"] == fp32["wire_send_bytes"] / 2
    assert bf16["local_bytes"] == fp32["local_bytes"]
    assert bf16["shipped_bytes"] == fp32["shipped_bytes"]


def test_task_mask_scales_flops_not_bytes():
    plan = _plan()
    full = LocalityLedger().note_dispatch(plan)
    t_cap = plan.task_count.max()
    task_on = np.zeros((plan.nparts, t_cap), dtype=bool)  # everything masked
    masked = LocalityLedger().note_dispatch(plan, task_on=task_on)
    assert masked["total_flops"] == 0.0 and masked["local_flops"] == 0.0
    assert masked["referenced_bytes"] == full["referenced_bytes"]


def test_moved_blocks_ranks_refetches():
    plan = _plan()
    lld = LocalityLedger(top_k=5)
    for _ in range(3):
        lld.note_dispatch(plan)
    mb = lld.moved_blocks()
    assert mb, "p2p plan over 4 workers must ship something"
    assert len(mb) <= 5
    assert all(mb[i]["fetches"] >= mb[i + 1]["fetches"]
               for i in range(len(mb) - 1))
    assert all(r["fetches"] % 3 == 0 for r in mb)  # same plan, 3 dispatches


def test_install_refuses_unverified_cache():
    with pytest.raises(ValueError, match="verified plans"):
        LocalityLedger().install(types.SimpleNamespace(verify="off"))
    ok = types.SimpleNamespace(verify="cached-once")
    lld = LocalityLedger().install(ok)
    assert ledger_of(ok) is lld
    assert ledger_of(None) is None
    assert ledger_of(types.SimpleNamespace()) is None


def test_locality_iteration_noop_without_ledger():
    cache = types.SimpleNamespace()
    assert locality_snapshot(cache) is None
    assert locality_iteration(cache, None, None, iteration=0, driver="x") == {}


# ---------------------------------------------------------------------------
# executed-task-graph analytics
# ---------------------------------------------------------------------------


def test_critical_path_dominates_every_worker():
    plan = _plan()
    an = analyze_plan(plan)
    assert (an.slack >= -1e-9).all()
    assert an.critical_path >= an.busy.max() - 1e-9
    assert an.critical_path == pytest.approx(an.cp_exchange + an.cp_compute)
    assert an.cp_compute == float(plan.task_count.max())
    assert an.whatif_zero_exchange == an.cp_compute
    assert an.whatif_perfect_balance <= an.critical_path + 1e-9
    assert len(an.rounds) == len(plan.a_offsets) + len(plan.b_offsets)
    d = an.as_dict()
    assert d["units"] == "task-equivalents"
    json.dumps(d)  # JSON-safe


def test_analyze_plan_rejects_bad_task_count():
    plan = _plan()
    with pytest.raises(ValueError, match="task_count shape"):
        analyze_plan(plan, task_count=np.zeros(plan.nparts + 1))


def test_whatif_rebalanced_predicts_gain_on_skew():
    m = random_block_matrix(256, BS, 0.25, seed=5)
    skew = np.zeros(m.coords.shape[0], dtype=np.int32)
    plan = make_spgemm_plan(m.coords, m.coords, 4, BS,
                            a_owner=skew, b_owner=skew)
    w = whatif_rebalanced(plan, m.coords)
    assert w["predicted_gain"] > 1.0
    assert w["after"].critical_path < w["before"].critical_path
    # the proposed cut spreads the blocks and lands near perfect balance
    assert len(np.unique(w["a_owner"])) > 1
    assert w["after"].cp_compute <= 1.5 * w["after"].compute.mean()
    # the re-plan is analyzable against the ledger too: conservation holds
    prov = plan_byte_provenance(w["plan"])
    assert np.array_equal(prov["local"] + prov["shipped"], prov["referenced"])


def test_project_seconds_calibrates():
    an = analyze_plan(_plan())
    out = project_seconds(an, 2.0)
    assert out["critical_path_s"] == pytest.approx(2.0)
    assert out["seconds_per_unit"] == pytest.approx(2.0 / an.critical_path)
    assert out["perfect_balance_s"] <= out["critical_path_s"] + 1e-9
    assert out["zero_exchange_s"] <= out["critical_path_s"] + 1e-9


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------


def test_locality_table_renders(tmp_path):
    plan = _plan()
    lld = LocalityLedger()
    lld.note_dispatch(plan)
    an = analyze_plan(plan).as_dict()
    payload = dict(
        meta=dict(n=256, bs=BS, workers=4, initial_layout="morton"),
        locality=dict(random=dict(
            static=lld.summary(), rebalanced=lld.summary(),
            taskgraph=dict(before=an, after=an, predicted_gain=1.0))),
    )
    text = locality_table(payload)
    assert "locality report" in text and "== random ==" in text
    assert "[static" in text and "[rebalanced" in text
    assert "critical path" in text and "what-if" in text
    # round-trips through the CLI path
    p = tmp_path / "BENCH_locality.json"
    p.write_text(json.dumps(payload))
    from repro.obs.report import locality_from_file
    assert locality_from_file(str(p)) == text


# ---------------------------------------------------------------------------
# SPMD invariants (subprocess, 4 fake devices)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import json
import numpy as np, jax
from repro.core import BSMatrix
from repro.core.distributed import make_worker_mesh
from repro.dist import PlanCache, RebalancePolicy, dist_sp2_purify, scatter
from repro.obs import LOCALITY_ITER_KEYS, LocalityLedger

assert jax.device_count() == 4, jax.device_count()
mesh = make_worker_mesh(4)
out = {}

rng = np.random.default_rng(0)
n, bs = 64, 8
hm = 0.2 * rng.standard_normal((n, n)).astype(np.float32)
F = BSMatrix.from_dense(
    (hm + hm.T) / 2 + np.diag(np.linspace(-1, 1, n)).astype(np.float32), bs)
w = np.linalg.eigvalsh(np.asarray(F.to_dense(), np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
nocc = 20
kw = dict(idem_tol=1e-5, trunc_tau=1e-6, spamm_tau=1e-7, max_iter=40)

# -- ledger off vs on: bit-identical density matrix, rows gain the keys ------
dF = scatter(F, mesh)
d0, st0 = dist_sp2_purify(dF, nocc, lmin, lmax, cache=PlanCache(), **kw)
cache1 = PlanCache()
lld1 = LocalityLedger().install(cache1)
d1, st1 = dist_sp2_purify(dF, nocc, lmin, lmax, cache=cache1, **kw)
out["bit_identical"] = bool(np.array_equal(
    np.asarray(d0.to_dense()), np.asarray(d1.to_dense())))
out["off_rows_lack_keys"] = bool(all(
    not (set(LOCALITY_ITER_KEYS) & set(r)) for r in st0.per_iter))
out["on_rows_have_keys"] = bool(all(
    set(LOCALITY_ITER_KEYS) <= set(r) for r in st1.per_iter))
s1 = lld1.summary()
out["conserves"] = bool(abs(
    s1["local_bytes"] + s1["shipped_bytes"] - s1["referenced_bytes"]) < 1e-6)
out["dispatches"] = s1["dispatches"]
out["fracs"] = [s1["locality_flops"], s1["locality_bytes"]]
out["row_fracs_sane"] = bool(all(
    0.0 <= r["locality_flops"] <= 1.0 and 0.0 <= r["locality_bytes"] <= 1.0
    for r in st1.per_iter))

# -- skewed layout: rebalanced run measures strictly better locality ---------
skew = np.zeros(F.nnzb, dtype=np.int32)

def run(policy):
    cache = PlanCache()
    lld = LocalityLedger().install(cache)
    d, _st = dist_sp2_purify(scatter(F, mesh, owner=skew), nocc, lmin, lmax,
                             cache=cache, rebalance=policy, **kw)
    return d, lld.summary()

ds, stat = run(None)
dr, reb = run(RebalancePolicy())
out["skew_bit_identical"] = bool(np.array_equal(
    np.asarray(d0.to_dense()), np.asarray(dr.to_dense())))
out["locality_flops"] = [stat["locality_flops"], reb["locality_flops"]]
out["locality_bytes"] = [stat["locality_bytes"], reb["locality_bytes"]]
out["moved"] = len(reb["moved_blocks"])

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def locality_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_ledger_off_is_bit_identical(locality_results):
    assert locality_results["bit_identical"]
    assert locality_results["skew_bit_identical"]


def test_rows_gain_locality_keys_only_with_ledger(locality_results):
    assert locality_results["off_rows_lack_keys"]
    assert locality_results["on_rows_have_keys"]
    assert locality_results["row_fracs_sane"]


def test_real_run_conserves(locality_results):
    assert locality_results["conserves"]
    assert locality_results["dispatches"] > 0
    lf, lb = locality_results["fracs"]
    assert 0.0 <= lf <= 1.0 and 0.0 <= lb <= 1.0


def test_rebalanced_run_measures_better_locality(locality_results):
    stat, reb = locality_results["locality_flops"]
    assert reb > stat
    bstat, breb = locality_results["locality_bytes"]
    assert breb > bstat
    assert locality_results["moved"] > 0
