import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BSMatrix, LeafSpec, inner_masks, nnz_elements

from helpers import banded_matrix, random_block_matrix


@given(
    n=st.integers(5, 80),
    bs=st.sampled_from([4, 8, 16]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 10),
)
@settings(max_examples=25, deadline=None)
def test_dense_roundtrip(n, bs, density, seed):
    m = random_block_matrix(n, bs, density, seed)
    d = m.to_dense()
    m2 = BSMatrix.from_dense(d, bs)
    assert np.allclose(m2.to_dense(), d)
    assert m2.shape == (n, n)


def test_zero_blocks_not_stored():
    m = banded_matrix(64, 3, 8)
    nb = m.nblocks[0]
    assert m.nnzb < nb * nb  # off-band pruned
    d = m.to_dense()
    # every stored block is nonzero
    assert (m.block_norms() > 0).all()


def test_from_coo_and_extract():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 100, 200)
    cols = rng.integers(0, 100, 200)
    vals = rng.standard_normal(200)
    m = BSMatrix.from_coo((100, 100), 16, rows, cols, vals)
    dense = np.zeros((100, 100))
    np.add.at(dense, (rows, cols), vals)
    assert np.allclose(m.to_dense(), dense, atol=1e-6)
    got = m.get_elements(rows, cols)
    assert np.allclose(got, dense[rows, cols], atol=1e-6)
    # extraction of absent elements returns 0
    assert m.get_elements([99], [0])[0] == dense[99, 0]


def test_transpose():
    m = banded_matrix(50, 4, 8)
    assert np.allclose(m.transpose().to_dense(), m.to_dense().T)
    # double transpose identity incl. Morton canonical order
    m2 = m.transpose().transpose()
    assert np.array_equal(m2.coords, m.coords)
    assert np.allclose(np.asarray(m2.data), np.asarray(m.data))


def test_norms_and_trace():
    m = banded_matrix(40, 3, 8)
    d = m.to_dense()
    assert np.isclose(m.frobenius_norm(), np.linalg.norm(d), rtol=1e-5)
    assert np.isclose(m.trace(), np.trace(d), rtol=1e-5)


def test_from_blocks_sums_duplicates():
    data = np.ones((3, 4, 4), dtype=np.float32)
    coords = np.array([[0, 0], [0, 0], [1, 1]])
    m = BSMatrix.from_blocks((8, 8), 4, coords, data)
    assert m.nnzb == 2
    d = m.to_dense()
    assert np.allclose(d[:4, :4], 2.0)
    assert np.allclose(d[4:, 4:], 1.0)


def test_get_elements_empty_matrix():
    # regression: searchsorted into a zero-length code array used to IndexError
    z = BSMatrix.zeros((32, 32), 8)
    got = z.get_elements([0, 5, 31], [1, 2, 31])
    assert got.shape == (3,) and (got == 0).all()


def test_get_elements_empty_queries():
    m = banded_matrix(32, 2, 8)
    assert m.get_elements([], []).shape == (0,)


def test_to_dense_matches_block_loop():
    # vectorized scatter must equal the per-block loop reference exactly
    for n, bs, d, seed in [(40, 8, 0.3, 0), (56, 16, 0.7, 1), (24, 4, 0.0, 2)]:
        m = random_block_matrix(n, bs, d, seed)
        data = np.asarray(m.data)
        nbr, nbc = m.nblocks
        ref = np.zeros((nbr * bs, nbc * bs), dtype=data.dtype)
        for t in range(m.nnzb):
            i, j = m.coords[t]
            ref[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = data[t]
        assert np.array_equal(m.to_dense(), ref[:n, :n])


def test_to_dense_rectangular_partial_blocks():
    rng = np.random.default_rng(4)
    d = rng.standard_normal((37, 21)).astype(np.float32)
    m = BSMatrix.from_dense(d, 8)
    assert m.to_dense().shape == (37, 21)
    assert np.allclose(m.to_dense(), d)


def test_leaf_specs():
    m = banded_matrix(128, 5, 32)
    spec = LeafSpec("block_sparse", inner_bs=8)
    masks = inner_masks(m, spec)
    assert masks.shape == (m.nnzb, 4, 4)
    # block-sparse leaf stores fewer elements than dense leaf
    assert nnz_elements(m, spec) <= nnz_elements(m, LeafSpec("dense"))
    # stored elements cover all actual nonzeros
    assert nnz_elements(m, spec) >= int((m.to_dense() != 0).sum())
