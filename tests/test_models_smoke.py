"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs, and decode-vs-forward parity for cache correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced_config
from repro.models import model as model_mod
from repro.models import transformer


def _batch(cfg, B, S, rng):
    if cfg.frontend == "audio_stub":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.frontend_dim)), jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        return {
            "patches": jnp.asarray(
                rng.standard_normal((B, cfg.num_patches, cfg.d_model)), jnp.float32
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S - cfg.num_patches)), jnp.int32
            ),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = _batch(cfg, B, S, rng)
    state = model_mod.init_train_state(jax.random.key(0), cfg)
    logits = transformer.apply(state["params"], cfg, None, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = jax.jit(model_mod.make_train_step(cfg, None, compute_dtype=jnp.float32))
    l0 = None
    for _ in range(4):
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
        l0 = float(m["loss"]) if l0 is None else l0
    assert float(m["loss"]) < l0  # learns something in 4 steps


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads_and_shapes(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 1e8
    # abstract init works at full size without allocation
    params, axes = transformer.abstract_params(cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(n - analytic) / analytic < 0.02, (n, analytic)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m", "recurrentgemma-9b", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through decode_step must reproduce the
    forward logits (validates KV caches, ring buffers, recurrent states)."""
    cfg = reduced_config(arch)
    rng = np.random.default_rng(1)
    B, S = 2, 12
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    params, _ = transformer.init_params(jax.random.key(3), cfg)
    full = transformer.apply(params, cfg, None, {"tokens": jnp.asarray(tokens)})
    cache = transformer.init_cache(cfg, B, S, jnp.float32)
    serve = jax.jit(
        model_mod.make_serve_step(cfg, None, compute_dtype=jnp.float32),
        static_argnames=(),
    )
    for pos in range(S):
        logits, cache = serve(
            params, cache, jnp.asarray(tokens[:, pos : pos + 1]), jnp.int32(pos)
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full[:, pos]),
            rtol=5e-3,
            atol=5e-3,
        )


def test_shape_skips_follow_spec():
    skips = {
        (a, s): get_config(a).supports(SHAPES[s])[0] for a in ARCH_IDS for s in SHAPES
    }
    # encoders skip decode
    assert not skips[("hubert-xlarge", "decode_32k")]
    assert not skips[("hubert-xlarge", "long_500k")]
    # sub-quadratic archs run long_500k, pure attention archs do not
    assert skips[("mamba2-370m", "long_500k")]
    assert skips[("recurrentgemma-9b", "long_500k")]
    assert not skips[("qwen2-72b", "long_500k")]
    # everyone trains and prefills
    assert all(skips[(a, "train_4k")] for a in ARCH_IDS)
    assert all(skips[(a, "prefill_32k")] for a in ARCH_IDS)
    assert sum(v for v in skips.values()) == 31


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-9b"])
def test_decode_int8_kv_close_to_forward(arch):
    """Quantized serving: int8 KV cache decode tracks fp32 forward closely."""
    cfg = reduced_config(arch)
    rng = np.random.default_rng(5)
    B, S = 2, 10
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    params, _ = transformer.init_params(jax.random.key(3), cfg)
    full = transformer.apply(params, cfg, None, {"tokens": jnp.asarray(tokens)})
    cache = transformer.init_cache(cfg, B, S, jnp.int8)
    serve = jax.jit(model_mod.make_serve_step(cfg, None, compute_dtype=jnp.float32))
    errs = []
    for pos in range(S):
        logits, cache = serve(
            params, cache, jnp.asarray(tokens[:, pos : pos + 1]), jnp.int32(pos)
        )
        errs.append(float(jnp.abs(logits[:, 0] - full[:, pos]).max()))
    scale = float(jnp.abs(full).max())
    assert max(errs) < 0.05 * scale, (max(errs), scale)
