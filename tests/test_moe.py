"""MoE dispatch correctness: capacity semantics, grouped-GEMM paths, EP."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe


def _ref_moe(p, x, num_experts, top_k, act="silu"):
    """Dense reference: route every pair, no capacity drops."""
    B, S, D = x.shape
    xf = np.asarray(x).reshape(-1, D)
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :top_k]
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        gates = probs[t, top[t]]
        gates = gates / gates.sum()
        for e, g in zip(top[t], gates):
            h = xf[t] @ np.asarray(p["w1"])[e]
            if act == "silu":
                h = h / (1 + np.exp(-h)) * (xf[t] @ np.asarray(p["wg"])[e])
            y = h @ np.asarray(p["w2"])[e]
            out[t] += g * y
    return out.reshape(B, S, D)


def test_moe_fallback_matches_reference():
    key = jax.random.key(0)
    D, F, E, K = 16, 32, 4, 2
    p, _ = moe.moe_init(key, D, F, E, "silu")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, D)), jnp.float32)
    # capacity_factor high enough that nothing drops
    out = moe.moe_apply(p, x, None, num_experts=E, top_k=K, act="silu", capacity_factor=8.0)
    ref = _ref_moe(p, x, E, K)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-3)


def test_moe_block_spmm_gemm_path():
    key = jax.random.key(1)
    D, F, E, K = 16, 32, 4, 2
    p, _ = moe.moe_init(key, D, F, E, "silu")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 16, D)), jnp.float32)
    a = moe.moe_apply(
        p, x, None, num_experts=E, top_k=K, act="silu", capacity_factor=8.0, gemm_impl="einsum"
    )
    b = moe.moe_apply(
        p,
        x,
        None,
        num_experts=E,
        top_k=K,
        act="silu",
        capacity_factor=8.0,
        gemm_impl="block_spmm",
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_capacity_drops_overflow():
    key = jax.random.key(2)
    D, F, E, K = 8, 16, 2, 1
    p, _ = moe.moe_init(key, D, F, E, "silu")
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)
    cap = 2  # = ceil(16*1*0.25/2)
    out = moe.moe_apply(p, x, None, num_experts=E, top_k=K, act="silu", capacity_factor=0.25)
    # expected survivors: first `cap` arrivals per expert (stable order)
    logits = np.asarray(x).reshape(-1, D) @ np.asarray(p["router"])
    choice = logits.argmax(-1)
    expected = sum(min(cap, int((choice == e).sum())) for e in range(E))
    nonzero_tokens = int((np.abs(np.asarray(out)[0]).sum(-1) > 1e-9).sum())
    assert nonzero_tokens == expected
    assert nonzero_tokens < 16  # something actually dropped


def test_moe_dropless_decode_no_drops():
    key = jax.random.key(3)
    D, F, E, K = 8, 16, 4, 2
    p, _ = moe.moe_init(key, D, F, E, "silu")
    # adversarial router: everything to one expert
    p["router"] = jnp.zeros((D, E)).at[:, 1].set(100.0) + 1e-3
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.abs(rng.standard_normal((1, 8, D))), jnp.float32)
    out = moe.moe_apply(p, x, None, num_experts=E, top_k=K, act="silu", dropless=True)
    nonzero_tokens = int((np.abs(np.asarray(out)[0]).sum(-1) > 1e-9).sum())
    assert nonzero_tokens == 8  # every token served


_EP_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import Mesh
from repro.models import moe
from repro.sharding.rules import MeshCtx

assert jax.device_count() == 8
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
ctx = MeshCtx(mesh=mesh)
key = jax.random.key(0)
D, F, E, K = 16, 32, 8, 2
p, _ = moe.moe_init(key, D, F, E, "silu")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 8, D)), jnp.float32)
ep = moe.moe_apply(p, x, ctx, num_experts=E, top_k=K, act="silu", capacity_factor=8.0)
local = moe.moe_apply(p, x, None, num_experts=E, top_k=K, act="silu", capacity_factor=8.0)
err = float(np.abs(np.asarray(ep) - np.asarray(local)).max())
print("RESULT " + json.dumps({"err": err}))
"""


def test_expert_parallel_matches_local():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _EP_SCRIPT], env=env, capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    assert json.loads(line[7:])["err"] < 1e-3


_DISPATCH_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import Mesh
from repro.models import moe
from repro.sharding.rules import MeshCtx

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
ctx = MeshCtx(mesh=mesh)
key = jax.random.key(0)
D, F, E, K = 16, 32, 8, 2
p, _ = moe.moe_init(key, D, F, E, "silu")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 1, D)), jnp.float32)
ref = moe.moe_apply(p, x, None, num_experts=E, top_k=K, act="silu", dropless=True)
disp = moe.moe_apply(p, x, ctx, num_experts=E, top_k=K, act="silu", dropless=True, token_dispatch=True)
err = float(np.abs(np.asarray(disp) - np.asarray(ref)).max())
print("RESULT " + json.dumps({"err": err}))
"""


def test_token_dispatch_decode_matches_local():
    """Decode dispatch mode (tokens move, weights resident) == local MoE."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _DISPATCH_SCRIPT],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    assert json.loads(line[7:])["err"] < 1e-4
