"""Observability subsystem tests (repro.obs) + driver telemetry invariants.

Unit tests run in-process (tracer nesting, null-tracer no-ops, the shared
timing idioms, Chrome-trace export/validation, report math, wall-clock
policy calibration).  SPMD invariants — tracing-off bit-identity, counter
conservation on a zero-miss replay, per-iteration row schema stability
across both iterative drivers, one trace track per worker — run in a
subprocess with 4 fake CPU devices, same harness as test_dist.py.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.dist.balance import RebalancePolicy, WorkerLoad, calibrate_policy
from repro.obs import (
    NULL_TRACER,
    SHARED_ITER_KEYS,
    IterationScope,
    Tracer,
    chrome_trace_events,
    run_metrics,
    timed_into,
    tracer_of,
    utilization_from_file,
    validate_chrome_trace,
    worker_utilization,
    write_chrome_trace,
)
from repro.core.cache import SymbolicCache


class Tick:
    """Deterministic clock: advances 1.0 s per call."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# -- tracer core --------------------------------------------------------------

def test_span_nesting_and_durations():
    tr = Tracer(clock=Tick(), sync=False)
    with tr.span("phase", cat="phase"):
        with tr.span("inner") as sp:
            sp.args.update(k=1)
        tr.instant("marker", cat="m", x=2)
    assert [s.name for s in tr.spans] == ["phase", "inner"]
    assert tr.spans[0].parent == -1
    assert tr.spans[1].parent == 0
    assert tr.spans[1].args == {"k": 1}
    assert all(s.dur > 0 for s in tr.spans)
    # inner closed before outer, nested inside it
    assert tr.spans[0].t0 < tr.spans[1].t0 <= tr.spans[1].t1 < tr.spans[0].t1
    (name, cat, t, parent, args) = tr.instants[0]
    assert (name, cat, parent, args) == ("marker", "m", 0, {"x": 2})
    assert tr._stack == []


def test_counters_and_gauges_register_once():
    tr = Tracer(sync=False)
    c = tr.counter("bytes")
    c.add(3)
    tr.counter("bytes").add(4)  # same object
    assert tr.counter("bytes") is c and c.value == 7
    tr.gauge("imb").set(1.5)
    m = tr.metrics_flat()
    assert m["bytes"] == 7 and m["imb"] == 1.5 and m["spans_recorded"] == 0
    assert len(tr._counter_events) == 3  # two adds + one set


def test_null_tracer_is_inert():
    assert not NULL_TRACER and not NULL_TRACER.enabled
    with NULL_TRACER.span("x", cat="c", a=1) as sp:
        sp.worker_costs = [1, 2]  # annotations vanish
        sp.args.update(k=1)
    assert sp.worker_costs is None and sp.args == {}
    NULL_TRACER.counter("c").add(5)
    NULL_TRACER.gauge("g").set(5)
    NULL_TRACER.instant("i")
    assert NULL_TRACER.metrics_flat() == {}
    assert NULL_TRACER.sync("v") == "v"


def test_tracer_of_rides_on_the_cache():
    assert tracer_of(None) is NULL_TRACER
    c = SymbolicCache()
    assert tracer_of(c) is NULL_TRACER
    tr = Tracer(sync=False)
    c.tracer = tr
    assert tracer_of(c) is tr
    c.tracer = None  # assigning None disables tracing (normalized)
    assert tracer_of(c) is NULL_TRACER and c.tracer is NULL_TRACER
    assert SymbolicCache(tracer=tr).tracer is tr


# -- shared timing idioms -----------------------------------------------------

def test_timed_into_accumulates_and_emits_span():
    cache = SymbolicCache()
    tr = Tracer(clock=Tick(), sync=False)
    with timed_into(cache, "symbolic_s", tr, "descent", cat="symbolic", n=3):
        pass
    assert cache.symbolic_s > 0
    assert [s.name for s in tr.spans] == ["descent"]
    assert tr.spans[0].args == {"n": 3}
    # disabled tracer: still accumulates, no span
    before = cache.symbolic_s
    with timed_into(cache, "symbolic_s", NULL_TRACER, "descent") as t:
        pass
    assert cache.symbolic_s > before and t.elapsed >= 0
    # no accumulator object at all
    with timed_into(None, "x", tr, None):
        pass
    assert len(tr.spans) == 1


def test_iteration_scope_row_schema():
    cache = SymbolicCache()
    tr = Tracer(clock=Tick(), sync=False)
    with IterationScope(cache, 2, tr, name="sp2_iteration") as scope:
        cache.get_or_build(("k",), lambda: 1)
        row = scope.row(nnzb=7, idem=0.5)
    assert set(SHARED_ITER_KEYS) <= row.keys()
    assert row["iteration"] == 2 and row["nnzb"] == 7 and row["idem"] == 0.5
    assert row["cache_misses"] == 1 and row["wall_s"] > 0
    assert tr.spans[0].name == "sp2_iteration" and tr.spans[0].args["i"] == 2
    # cache-less stage scope still yields the full schema with zero counters
    with IterationScope(None, None, tr, name="stage", cat="phase") as st:
        d = st.delta()
    assert d["cache_hits"] == 0 and d["plan_build_s"] == 0.0


def test_cache_plan_counters_flow_to_tracer():
    tr = Tracer(sync=False)
    cache = SymbolicCache(tracer=tr)
    cache.get_or_build(("spgemm", 1), lambda: "v")
    cache.get_or_build(("spgemm", 1), lambda: "v")
    m = run_metrics(cache)
    assert m["plan_misses"] == 1 and m["plan_hits"] == 1
    assert m["hits"] == 1 and m["misses"] == 1  # cache.stats() merged in
    assert any(s.name == "plan_build" for s in tr.spans)
    # tracing off: run_metrics is exactly cache.stats()
    cache2 = SymbolicCache()
    cache2.get_or_build(("add", 1), lambda: "v")
    assert run_metrics(cache2) == cache2.stats()


# -- export + report ----------------------------------------------------------

def _synthetic_tracer():
    tr = Tracer(clock=Tick(), sync=False)
    with tr.span("phase", cat="phase"):
        with tr.span("dispatch", cat="dispatch") as sp:
            sp.worker_costs = np.array([2.0, 1.0, 0.0, 1.0])
            tr.counter("tasks_executed").add(4)
        with tr.span("dispatch", cat="dispatch") as sp:
            sp.worker_costs = np.array([1.0, 1.0, 1.0, 1.0])
            tr.instant("exchange_round", cat="exchange", bytes=256)
    return tr


def test_chrome_trace_export_and_validation(tmp_path):
    tr = _synthetic_tracer()
    summary = write_chrome_trace(tr, str(tmp_path / "t.json"))
    assert summary["host_spans"] == 3
    assert summary["workers"] == 4  # one track per worker
    assert "tasks_executed" in summary["counters"]
    with open(tmp_path / "t.json") as fh:
        trace = json.load(fh)
    assert validate_chrome_trace(trace) == summary
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "M"}
    assert "thread_name" in names and trace["displayTimeUnit"] == "ms"


def test_validate_rejects_misnested_pairs():
    bad = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
         "args": {"name": "host"}},
        {"ph": "B", "pid": 0, "tid": 0, "ts": 0.0, "name": "a", "cat": "c"},
        {"ph": "B", "pid": 0, "tid": 0, "ts": 1.0, "name": "b", "cat": "c"},
        {"ph": "E", "pid": 0, "tid": 0, "ts": 2.0, "name": "a"},
    ]
    with pytest.raises(AssertionError):
        validate_chrome_trace(bad)


def test_worker_utilization_math(tmp_path):
    tr = _synthetic_tracer()
    util = worker_utilization(tr)
    # each dispatch span lasts exactly 2 ticks (the counter/instant inside
    # consumes one); step 1 costs [2,1,0,1] -> busy [2,1,0,1]; step 2 is
    # balanced -> +2 each; window = 4
    assert util["nparts"] == 4 and util["window_s"] == pytest.approx(4.0)
    assert util["busy_s"] == pytest.approx([4.0, 3.0, 2.0, 3.0])
    assert util["busy_frac"] == pytest.approx([1.0, 0.75, 0.5, 0.75])
    assert util["timeline_imbalance"] == pytest.approx(4.0 / 3.0)
    # the written trace file carries the same picture on its own
    write_chrome_trace(tr, str(tmp_path / "t.json"))
    util2 = utilization_from_file(str(tmp_path / "t.json"))
    assert util2["busy_s"] == pytest.approx(util["busy_s"], abs=1e-6)
    assert util2["timeline_imbalance"] == pytest.approx(
        util["timeline_imbalance"], abs=1e-6)


def test_attributed_busy_never_nests():
    tr = Tracer(clock=Tick(), sync=False)
    with tr.span("outer", cat="collective") as outer:
        outer.worker_costs = np.array([1.0, 1.0])
        with tr.span("inner", cat="dispatch") as inner:
            inner.worker_costs = np.array([2.0, 1.0])
    ev = chrome_trace_events(tr)
    busy = [e for e in ev if e.get("pid") == 1 and e["ph"] == "B"]
    # only the outermost attributed span feeds the worker tracks
    assert len(busy) == 2 and all(e["name"] == "outer" for e in busy)


# -- wall-clock policy calibration --------------------------------------------

def _load(tasks, recv, send, blocks, wall, bs=8):
    z = lambda v: np.asarray(v, dtype=np.float64)
    return WorkerLoad(nparts=len(tasks), bs=bs, tasks=z(tasks),
                      recv_bytes=z(recv), send_bytes=z(send),
                      blocks=z(blocks), wall_s=wall)


def test_calibrate_policy_recovers_coefficients():
    rng = np.random.default_rng(3)
    k_t, k_r, k_s, k_b = 1e-4, 5e-5, 2.5e-5, 1e-5
    blk = 8 * 8 * 4
    loads = []
    for _ in range(8):
        t = rng.uniform(50, 500, size=4)
        r = rng.uniform(0, 40, size=4) * blk
        s = rng.uniform(0, 40, size=4) * blk
        b = rng.uniform(5, 50, size=4)
        wall = (k_t * t.max() + k_r * r.max() / blk
                + k_s * s.max() / blk + k_b * b.max())
        loads.append(_load(t, r, s, b, wall))
    policy, rep = calibrate_policy(loads, RebalancePolicy())
    assert rep["fitted"] and rep["samples"] == 8
    assert rep["task_s"] == pytest.approx(k_t, rel=1e-6)
    assert policy.recv_cost == pytest.approx(k_r / k_t, rel=1e-5)
    assert policy.send_cost == pytest.approx(k_s / k_t, rel=1e-5)
    assert policy.block_cost == pytest.approx(k_b / k_t, rel=1e-5)
    assert rep["rms_resid_s"] == pytest.approx(0.0, abs=1e-9)
    # threshold is preserved — only the cost ratios are measured
    assert policy.threshold == RebalancePolicy().threshold


def test_calibrate_policy_needs_enough_samples():
    base = RebalancePolicy()
    ld = _load([10, 20], [0, 0], [0, 0], [1, 2], 0.5)
    policy, rep = calibrate_policy([ld] * 3, base)
    assert policy is base and not rep["fitted"]
    # unwalled loads don't count as samples
    nowall = _load([10, 20], [0, 0], [0, 0], [1, 2], None)
    _, rep2 = calibrate_policy([nowall] * 10, base)
    assert rep2["samples"] == 0 and not rep2["fitted"]


def test_workerload_add_accumulates_wall():
    a = _load([1, 2], [0, 0], [0, 0], [1, 1], 0.25)
    b = _load([2, 1], [0, 0], [0, 0], [1, 1], 0.5)
    assert (a + b).wall_s == pytest.approx(0.75)
    c = _load([1, 1], [0, 0], [0, 0], [1, 1], None)
    assert (c + c).wall_s is None
    assert (a + c).wall_s == pytest.approx(0.25)


# -- SPMD invariants (subprocess, 4 fake devices) -----------------------------

_SCRIPT = r"""
import json, os, tempfile
import numpy as np, jax
from repro.core import BSMatrix
from repro.core.distributed import make_worker_mesh
from repro.dist import (PlanCache, RebalancePolicy, dist_sp2_purify,
                        dist_localized_inverse_factorization, scatter)
from repro.obs import (SHARED_ITER_KEYS, Tracer, run_metrics,
                       utilization_from_file, validate_chrome_trace,
                       worker_utilization, write_chrome_trace)

assert jax.device_count() == 4, jax.device_count()
mesh = make_worker_mesh(4)
out = {}

rng = np.random.default_rng(0)
n, bs = 64, 8
b = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - 5), min(n, i + 6)
    b[i, lo:hi] = rng.standard_normal(hi - lo)
S = BSMatrix.from_dense(b @ b.T / n + np.eye(n, dtype=np.float32), bs)
hm = 0.2 * rng.standard_normal((n, n)).astype(np.float32)
F = BSMatrix.from_dense(
    (hm + hm.T) / 2 + np.diag(np.linspace(-1, 1, n)).astype(np.float32), bs)
w = np.linalg.eigvalsh(np.asarray(F.to_dense(), np.float64))
lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05
nocc = 20
kw = dict(idem_tol=1e-5, trunc_tau=1e-6, spamm_tau=1e-7, max_iter=40)

# -- tracing off vs on: bit-identical results, identical row schema ----------
dF = scatter(F, mesh)
d0, st0 = dist_sp2_purify(dF, nocc, lmin, lmax, cache=PlanCache(), **kw)
tr = Tracer()
d1, st1 = dist_sp2_purify(dF, nocc, lmin, lmax, cache=PlanCache(),
                          tracer=tr, **kw)
out["sp2_bit_identical"] = bool(np.array_equal(
    np.asarray(d0.to_dense()), np.asarray(d1.to_dense())))
out["sp2_rows_same_schema"] = [sorted(st0.per_iter[0]), sorted(st1.per_iter[0])]
out["sp2_rows_shared_keys"] = bool(all(
    set(SHARED_ITER_KEYS) <= set(pi) for st in (st0, st1) for pi in st.per_iter))
out["sp2_spans"] = len(tr.spans)
out["sp2_span_names"] = sorted({s.name for s in tr.spans})[:20]

# -- counter conservation on a zero-miss replay ------------------------------
tr2 = Tracer()
cache = PlanCache(tracer=tr2)
dS = scatter(S, mesh)
z1, i1 = dist_localized_inverse_factorization(
    dS, cache, tol=1e-7, max_iter=40, trunc_tau=1e-6, spamm_tau=1e-7)
h1, m1 = cache.hits, cache.misses
p1 = dict(hits=tr2.counter("plan_hits").value,
          misses=tr2.counter("plan_misses").value)
z2, i2 = dist_localized_inverse_factorization(
    dS, cache, tol=1e-7, max_iter=40, trunc_tau=1e-6, spamm_tau=1e-7)
out["replay_misses"] = [int(cache.misses - m1),
                        int(tr2.counter("plan_misses").value - p1["misses"])]
out["replay_hits_equal"] = bool(
    (cache.hits - h1) == (tr2.counter("plan_hits").value - p1["hits"]))
out["counters_conserved"] = bool(
    tr2.counter("plan_hits").value == cache.hits
    and tr2.counter("plan_misses").value == cache.misses)
out["inv_rows_shared_keys"] = bool(all(
    set(SHARED_ITER_KEYS) <= set(pi) for st in (i1, i2) for pi in st.per_iter))
out["run_metrics_merged"] = bool(
    set(cache.stats()) <= set(run_metrics(cache))
    and run_metrics(cache)["plan_hits"] == cache.hits)

# -- rebalanced run feeds wall-clock calibration -----------------------------
skew = np.zeros(F.nnzb, dtype=np.int32)
dFs = scatter(F, mesh, owner=skew)
d2, st2 = dist_sp2_purify(dFs, nocc, lmin, lmax, cache=PlanCache(),
                          rebalance=RebalancePolicy(), **kw)
out["rebalanced_bit_identical"] = bool(np.array_equal(
    np.asarray(d0.to_dense()), np.asarray(d2.to_dense())))
out["calibration"] = st2.calibration
out["calibration_untracked"] = st0.calibration is None

# -- exported trace: valid, one track per worker, utilization sane -----------
path = os.path.join(tempfile.mkdtemp(), "trace.json")
summary = write_chrome_trace(tr2, path)
out["trace_summary"] = summary
util = worker_utilization(tr2)
out["util_nparts"] = util["nparts"]
out["util_fracs_sane"] = bool(all(0.0 <= f <= 1.0 + 1e-9
                                  for f in util["busy_frac"]))
futil = utilization_from_file(path)
out["util_file_close"] = bool(abs(
    futil["timeline_imbalance"] - util["timeline_imbalance"]) < 1e-6)

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def obs_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_tracing_off_is_bit_identical(obs_results):
    assert obs_results["sp2_bit_identical"]
    assert obs_results["rebalanced_bit_identical"]


def test_driver_rows_share_one_schema(obs_results):
    a, b = obs_results["sp2_rows_same_schema"]
    assert a == b  # traced and untraced rows carry identical keys
    assert obs_results["sp2_rows_shared_keys"]
    assert obs_results["inv_rows_shared_keys"]


def test_traced_sp2_records_expected_spans(obs_results):
    assert obs_results["sp2_spans"] > 0
    names = set(obs_results["sp2_span_names"])
    assert {"sp2_purify", "sp2_iteration", "dist_spamm",
            "plan_build"} <= names


def test_zero_miss_replay_conserves_counters(obs_results):
    # second identical run: no plan-cache misses, and the tracer's
    # plan_hits/plan_misses counters agree with the cache's own counters
    assert obs_results["replay_misses"] == [0, 0]
    assert obs_results["replay_hits_equal"]
    assert obs_results["counters_conserved"]
    assert obs_results["run_metrics_merged"]


def test_rebalanced_run_reports_calibration(obs_results):
    cal = obs_results["calibration"]
    assert cal is not None and "samples" in cal and "fitted" in cal
    assert obs_results["calibration_untracked"]


def test_exported_trace_one_track_per_worker(obs_results):
    s = obs_results["trace_summary"]
    assert s["workers"] == 4
    assert s["host_spans"] > 0 and s["events"] > s["host_spans"]
    assert obs_results["util_nparts"] == 4
    assert obs_results["util_fracs_sane"]
    assert obs_results["util_file_close"]
