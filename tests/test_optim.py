import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    cosine_lr,
    decompress_grads,
    error_feedback_update,
)


def test_adamw_converges_quadratic():
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8), jnp.float32)
    params = {"w": jnp.zeros(8)}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, state = adamw_update(
            grads, state, params, lr=0.05, weight_decay=0.0
        )
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    # below threshold: untouched
    same, _ = clip_by_global_norm(g, 1e9)
    assert float(jnp.abs(same["a"] - 3.0).max()) < 1e-6


def test_cosine_lr_shape():
    peak, warm, total = 1e-3, 10, 100
    lrs = [float(cosine_lr(jnp.asarray(s), peak=peak, warmup=warm, total=total)) for s in range(total)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(peak, rel=1e-3)
    assert lrs[-1] < 0.2 * peak
    assert np.argmax(lrs) == warm


@given(seed=st.integers(0, 50), scale=st.floats(1e-4, 1e3))
@settings(max_examples=20, deadline=None)
def test_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((16, 16)) * scale, jnp.float32)}
    q, s = compress_grads(g)
    back = decompress_grads(q, s)
    max_err = float(jnp.abs(back["w"] - g["w"]).max())
    # quantization error bounded by half a quantization step
    step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert max_err <= 0.51 * step + 1e-12
    assert q["w"].dtype == jnp.int8  # 4x wire reduction vs f32


def test_error_feedback_accumulates():
    rng = np.random.default_rng(1)
    true_g = jnp.asarray(rng.standard_normal(64), jnp.float32)
    err = {"w": jnp.zeros(64)}
    sent_sum = jnp.zeros(64)
    for _ in range(50):
        intended = {"w": true_g + err["w"]}
        q, s = compress_grads(intended)
        transmitted = decompress_grads(q, s)
        err = error_feedback_update(intended, transmitted)
        sent_sum = sent_sum + transmitted["w"]
    # long-run average of transmitted gradients converges to the true gradient
    assert float(jnp.abs(sent_sum / 50 - true_g).max()) < 0.02
