"""Outer-product SpGEMM (the paper's §5 future work) — plan invariants and
host-simulated execution vs dense oracle."""

import numpy as np
import pytest

from repro.core import BSMatrix, multiply
from repro.core.outer import choose_schedule, make_outer_plan, plan_outer_stats
from repro.core.schedule import make_spgemm_plan, plan_stats
from repro.core.spgemm import spgemm_symbolic

from helpers import banded_matrix, random_block_matrix


def _simulate_outer(plan, a_data, b_data):
    P = plan.nparts
    bs = plan.bs
    a_data = np.asarray(a_data)
    b_data = np.asarray(b_data)
    a_store = np.zeros((P, plan.a_cap, bs, bs), np.float32)
    b_store = np.zeros((P, plan.b_cap, bs, bs), np.float32)
    for p in range(P):
        va = plan.a_store_valid[p]
        a_store[p][va] = a_data[plan.a_store_idx[p][va]]
        vb = plan.b_store_valid[p]
        b_store[p][vb] = b_data[plan.b_store_idx[p][vb]]
    # local partials
    partials = np.zeros((P, plan.p_cap + 1, bs, bs), np.float32)
    for p in range(P):
        for t in range(plan.task_count[p]):
            partials[p, plan.task_c[p, t]] += (
                a_store[p, plan.task_a[p, t]] @ b_store[p, plan.task_b[p, t]]
            )
    partials = partials[:, : plan.p_cap]
    # exchange + accumulate
    c = np.zeros((P, plan.c_cap + 1, bs, bs), np.float32)
    for dst in range(P):
        bufs = [partials[dst]]
        for d in plan.offsets:
            src = (dst - d) % P
            bufs.append(partials[src][plan.send[d][src]])
        allp = np.concatenate(bufs, axis=0)
        np.add.at(c[dst], plan.acc_idx[dst], allp)
    return c[:, : plan.c_cap]


@pytest.mark.parametrize(
    "builder",
    [
        lambda: banded_matrix(192, 14, 16, seed=1),
        lambda: random_block_matrix(192, 16, 0.25, seed=2),
    ],
)
@pytest.mark.parametrize("nparts", [3, 8])
def test_outer_simulation_matches_dense(builder, nparts):
    a = builder()
    plan = make_outer_plan(a.coords, a.coords, nparts, 16)
    c_stores = _simulate_outer(plan, a.data, a.data)
    ref = a.to_dense() @ a.to_dense()
    nc = plan.c_coords.shape[0]
    data = np.zeros((nc, 16, 16), np.float32)
    for p in range(plan.nparts):
        valid = plan.c_store_valid[p]
        data[plan.c_store_idx[p][valid]] = c_stores[p][valid]
    import jax.numpy as jnp

    out = BSMatrix(shape=a.shape, bs=16, coords=plan.c_coords, data=jnp.asarray(data))
    assert np.allclose(out.to_dense(), ref, atol=1e-3)


def test_outer_operands_are_all_local():
    """The defining property: every task's operands live on the task device."""
    a = random_block_matrix(128, 8, 0.3, seed=3)
    plan = make_outer_plan(a.coords, a.coords, 4, 8)
    tasks = spgemm_symbolic(a.coords, a.coords)
    assert int(plan.task_count.sum()) == tasks.num_tasks
    # operand slot indices never exceed the local store (no remote fetches)
    for p in range(4):
        n = plan.task_count[p]
        assert (plan.task_a[p, :n] < plan.a_cap).all()
        assert (plan.task_b[p, :n] < plan.b_cap).all()


def test_choose_schedule_picks_cheaper():
    a = banded_matrix(256, 10, 16, seed=4)
    kind, plan, stats = choose_schedule(a.coords, a.coords, 8, 16)
    other = (
        plan_outer_stats(make_outer_plan(a.coords, a.coords, 8, 16))
        if kind == "p2p"
        else plan_stats(make_spgemm_plan(a.coords, a.coords, 8, 16))
    )
    assert stats["recv_bytes_mean"] <= other["recv_bytes_mean"]


from hypothesis import given, settings
from hypothesis import strategies as st


@given(nparts=st.integers(2, 9), density=st.floats(0.1, 0.6), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_outer_partials_reach_owner_exactly_once(nparts, density, seed):
    """Conservation: every (producer, C-block) partial is delivered to the
    owner exactly once — locally or via exactly one send slot."""
    a = random_block_matrix(96, 8, density, seed)
    if a.nnzb == 0:
        return
    plan = make_outer_plan(a.coords, a.coords, nparts, 8)
    deliveries = np.zeros(plan.c_coords.shape[0], dtype=int)
    for src in range(nparts):
        g = plan.partial_c_global[src][plan.partial_valid[src]]
        own = plan.c_owner[g] == src
        np.add.at(deliveries, g[own], 1)
        for d in plan.offsets:
            slots = plan.send[d][src]
            cnt = plan.send_count[d][src]
            np.add.at(deliveries, plan.partial_c_global[src][slots[:cnt]], 1)
    produced = np.zeros(plan.c_coords.shape[0], dtype=int)
    for src in range(nparts):
        g = plan.partial_c_global[src][plan.partial_valid[src]]
        np.add.at(produced, g, 1)
    assert np.array_equal(deliveries, produced)
