import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quadtree import (
    build_quadtree_index,
    expand_prefix,
    morton_decode,
    morton_encode,
    morton_sort,
    quadtree_depth,
    quadtree_node_counts,
)

from helpers import banded_matrix, random_block_matrix


@given(
    st.lists(
        st.tuples(st.integers(0, 2**20 - 1), st.integers(0, 2**20 - 1)),
        min_size=1,
        max_size=100,
    )
)
@settings(max_examples=50, deadline=None)
def test_morton_roundtrip(coords):
    r = np.array([c[0] for c in coords], dtype=np.int64)
    c = np.array([c[1] for c in coords], dtype=np.int64)
    codes = morton_encode(r, c)
    r2, c2 = morton_decode(codes)
    assert np.array_equal(r, r2)
    assert np.array_equal(c, c2)


def test_morton_order_is_quadrant_recursive():
    # within a 2x2 grid: (0,0) < (0,1) < (1,0) < (1,1)
    codes = morton_encode(np.array([0, 0, 1, 1]), np.array([0, 1, 0, 1]))
    assert list(codes) == sorted(codes)
    # quadrant blocks of a 4x4 grid are contiguous in Morton order
    r, c = np.meshgrid(np.arange(4), np.arange(4), indexing="ij")
    codes = morton_encode(r.ravel(), c.ravel())
    order = np.argsort(codes)
    quadrant = (r.ravel()[order] // 2) * 2 + c.ravel()[order] // 2
    # each quadrant's 4 blocks appear consecutively
    assert all(len(set(quadrant[i : i + 4])) == 1 for i in range(0, 16, 4))


def test_morton_sort_permutation():
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 100, size=(50, 2))
    perm = morton_sort(coords)
    codes = morton_encode(coords[perm, 0], coords[perm, 1])
    assert np.all(np.diff(codes.astype(np.int64)) >= 0)


def test_node_counts_banded():
    # dense diagonal: leaf count = n, upper levels shrink by ~4x for diag
    n = 16
    coords = np.stack([np.arange(n), np.arange(n)], 1)
    counts = quadtree_node_counts(coords, depth=4)
    assert counts[-1] == n
    assert counts[0] == 1
    assert all(a <= b for a, b in zip(counts, counts[1:]))  # monotone down the tree


def test_expand_prefix():
    r0, r1, c0, c1 = expand_prefix(0b11, 1, 3)  # quadrant (1,1) at level 1, depth 3
    assert (r0, r1, c0, c1) == (4, 8, 4, 8)


def test_depth():
    assert quadtree_depth(1, 1) == 0
    assert quadtree_depth(2, 2) == 1
    assert quadtree_depth(5, 3) == 3


# -- QuadtreeIndex -----------------------------------------------------------


@given(n=st.integers(8, 80), bs=st.sampled_from([4, 8]), d=st.floats(0.05, 0.9), seed=st.integers(0, 6))
@settings(max_examples=20, deadline=None)
def test_quadtree_index_invariants(n, bs, d, seed):
    m = random_block_matrix(n, bs, d, seed)
    if m.nnzb == 0:
        return
    qt = m.quadtree_index()
    # level sizes match the implicit node counts
    assert qt.node_counts() == quadtree_node_counts(m.coords, depth=qt.depth)
    # child spans partition each next level, in order
    for k in range(qt.depth):
        cs = qt.child_start[k]
        assert cs[0] == 0 and cs[-1] == qt.prefixes[k + 1].size
        assert np.all(np.diff(cs) >= 1)  # every node has a nonzero child
        # every child's prefix >> 2 equals its parent's prefix
        parent = np.repeat(np.arange(qt.prefixes[k].size), np.diff(cs))
        assert np.array_equal(
            qt.prefixes[k + 1] >> np.uint64(2), qt.prefixes[k][parent]
        )
    # leaf spans cover the stack exactly
    for k in range(qt.depth + 1):
        ls = qt.leaf_start[k]
        assert ls[0] == 0 and ls[-1] == m.nnzb


@given(n=st.integers(8, 64), bs=st.sampled_from([4, 8]), seed=st.integers(0, 6))
@settings(max_examples=15, deadline=None)
def test_subtree_norms_match_dense(n, bs, seed):
    from repro.core.quadtree import expand_prefix

    m = random_block_matrix(n, bs, 0.4, seed)
    if m.nnzb == 0:
        return
    qt = m.quadtree_index()
    dense = m.to_dense().astype(np.float64)
    pad = np.zeros((m.nblocks[0] * bs, m.nblocks[1] * bs))
    pad[: dense.shape[0], : dense.shape[1]] = dense
    # root norm is the full Frobenius norm
    assert np.isclose(qt.norms[0][0], np.linalg.norm(pad), rtol=1e-5)
    # every node's subtree norm equals the norm of its bounding box
    for level in range(qt.depth + 1):
        for j, p in enumerate(qt.prefixes[level][:16]):  # cap for speed
            r0, r1, c0, c1 = expand_prefix(int(p), level, qt.depth)
            sub = pad[r0 * bs : r1 * bs, c0 * bs : c1 * bs]
            assert np.isclose(qt.norms[level][j], np.linalg.norm(sub), rtol=1e-5)


def test_quadtree_index_cached_on_matrix():
    m = banded_matrix(64, 3, 8)
    q1 = m.quadtree_index()
    q2 = m.quadtree_index()
    assert q1 is q2  # lazily built once per (matrix, depth)
    q3 = m.quadtree_index(depth=q1.depth + 2)
    assert q3 is not q1 and q3.depth == q1.depth + 2
    # fingerprints are structure-keyed: same codes + depth => same key
    m2 = banded_matrix(64, 3, 8, seed=9)  # same band structure, other values
    assert m2.quadtree_index().fingerprint == q1.fingerprint
    assert m.structure_key == m2.structure_key


def test_quadtree_index_empty_and_single():
    empty = build_quadtree_index(np.zeros((0, 2), dtype=np.int64))
    assert empty.nnzb == 0 and empty.num_nodes() == 0
    single = build_quadtree_index(np.array([[0, 0]]), np.array([2.0]), depth=0)
    assert single.depth == 0 and single.nnzb == 1
    assert np.isclose(single.norms[0][0], 2.0)


def test_boundaries_are_node_starts():
    m = banded_matrix(128, 5, 8)
    qt = m.quadtree_index()
    b = qt.boundaries()
    assert b[0] == 0 and b[-1] == m.nnzb
    # level-restricted boundaries are a subset of the merged set
    assert np.all(np.isin(qt.boundaries(level=1), b))
