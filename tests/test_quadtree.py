import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quadtree import (
    expand_prefix,
    morton_decode,
    morton_encode,
    morton_sort,
    quadtree_depth,
    quadtree_node_counts,
)


@given(
    st.lists(
        st.tuples(st.integers(0, 2**20 - 1), st.integers(0, 2**20 - 1)),
        min_size=1,
        max_size=100,
    )
)
@settings(max_examples=50, deadline=None)
def test_morton_roundtrip(coords):
    r = np.array([c[0] for c in coords], dtype=np.int64)
    c = np.array([c[1] for c in coords], dtype=np.int64)
    codes = morton_encode(r, c)
    r2, c2 = morton_decode(codes)
    assert np.array_equal(r, r2)
    assert np.array_equal(c, c2)


def test_morton_order_is_quadrant_recursive():
    # within a 2x2 grid: (0,0) < (0,1) < (1,0) < (1,1)
    codes = morton_encode(np.array([0, 0, 1, 1]), np.array([0, 1, 0, 1]))
    assert list(codes) == sorted(codes)
    # quadrant blocks of a 4x4 grid are contiguous in Morton order
    r, c = np.meshgrid(np.arange(4), np.arange(4), indexing="ij")
    codes = morton_encode(r.ravel(), c.ravel())
    order = np.argsort(codes)
    quadrant = (r.ravel()[order] // 2) * 2 + c.ravel()[order] // 2
    # each quadrant's 4 blocks appear consecutively
    assert all(len(set(quadrant[i : i + 4])) == 1 for i in range(0, 16, 4))


def test_morton_sort_permutation():
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 100, size=(50, 2))
    perm = morton_sort(coords)
    codes = morton_encode(coords[perm, 0], coords[perm, 1])
    assert np.all(np.diff(codes.astype(np.int64)) >= 0)


def test_node_counts_banded():
    # dense diagonal: leaf count = n, upper levels shrink by ~4x for diag
    n = 16
    coords = np.stack([np.arange(n), np.arange(n)], 1)
    counts = quadtree_node_counts(coords, depth=4)
    assert counts[-1] == n
    assert counts[0] == 1
    assert all(a <= b for a, b in zip(counts, counts[1:]))  # monotone down the tree


def test_expand_prefix():
    r0, r1, c0, c1 = expand_prefix(0b11, 1, 3)  # quadrant (1,1) at level 1, depth 3
    assert (r0, r1, c0, c1) == (4, 8, 4, 8)


def test_depth():
    assert quadtree_depth(1, 1) == 0
    assert quadtree_depth(2, 2) == 1
    assert quadtree_depth(5, 3) == 3
