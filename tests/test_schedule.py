"""Scheduler invariants — the paper's load-balance and locality claims,
verified structurally (no devices needed)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSMatrix, multiply, spgemm_symbolic
from repro.core.schedule import (
    make_spgemm_plan,
    partition_morton,
    partition_random,
    plan_stats,
    subtree_boundaries,
)

from helpers import banded_matrix, random_block_matrix


def _simulate(plan, a_data, b_data):
    """Host-side simulation of the SPMD execution (numpy, no jax devices)."""
    P = plan.nparts
    a_data = np.asarray(a_data)
    b_data = np.asarray(b_data)
    bs = plan.bs
    a_store = np.zeros((P, plan.a_cap, bs, bs), a_data.dtype)
    b_store = np.zeros((P, plan.b_cap, bs, bs), b_data.dtype)
    for p in range(P):
        va = plan.a_store_valid[p]
        a_store[p][va] = a_data[plan.a_store_idx[p][va]]
        vb = plan.b_store_valid[p]
        b_store[p][vb] = b_data[plan.b_store_idx[p][vb]]

    def build_local(x_store, offsets, sends):
        bufs = [[x_store[p]] for p in range(P)]
        for d in offsets:
            send = sends[d]
            for src in range(P):
                dst = (src + d) % P
                bufs[dst].append(x_store[src][send[src]])
        return [np.concatenate(b, axis=0) for b in bufs]

    if plan.exchange == "p2p":
        a_loc = build_local(a_store, plan.a_offsets, plan.a_send)
        b_loc = build_local(b_store, plan.b_offsets, plan.b_send)
    else:
        a_all = a_store.reshape(-1, bs, bs)
        b_all = b_store.reshape(-1, bs, bs)
        a_loc = [a_all] * P
        b_loc = [b_all] * P

    c = np.zeros((plan.c_coords.shape[0], bs, bs), np.float32)
    for p in range(P):
        cnt = plan.task_count[p]
        for t in range(cnt):
            slot = plan.task_c[p, t]
            g = plan.c_store_idx[p, slot]
            c[g] += a_loc[p][plan.task_a[p, t]] @ b_loc[p][plan.task_b[p, t]]
    return c


@pytest.mark.parametrize("placement", ["morton", "random"])
@pytest.mark.parametrize("exchange", ["p2p", "allgather"])
def test_plan_simulation_matches_dense(placement, exchange):
    a = banded_matrix(160, 12, 16, seed=1)
    plan = make_spgemm_plan(
        a.coords, a.coords, 8, 16, placement=placement, exchange=exchange
    )
    c = _simulate(plan, a.data, a.data)
    ref = a.to_dense() @ a.to_dense()
    out = BSMatrix(shape=(160, 160), bs=16, coords=plan.c_coords, data=jnp.asarray(c))
    assert np.allclose(out.to_dense(), ref, atol=1e-3)


def test_every_task_assigned_exactly_once():
    a = random_block_matrix(96, 8, 0.4, 2)
    tasks = spgemm_symbolic(a.coords, a.coords)
    plan = make_spgemm_plan(a.coords, a.coords, 5, 8, tasks=tasks)
    assert int(plan.task_count.sum()) == tasks.num_tasks


def test_load_balance_bound():
    # CHT claim: balanced regardless of structure
    for seed, builder in [
        (0, lambda: banded_matrix(256, 20, 16)),
        (1, lambda: random_block_matrix(256, 16, 0.3, 1)),
    ]:
        a = builder()
        plan = make_spgemm_plan(a.coords, a.coords, 8, 16)
        st = plan_stats(plan)
        assert st["task_balance"] < 1.6, st


def test_locality_reduces_communication():
    # Fig 1c, structurally: banded matrix under morton placement moves far
    # fewer bytes than under random placement, and far fewer than allgather
    a = banded_matrix(512, 20, 16, seed=4)
    morton = plan_stats(make_spgemm_plan(a.coords, a.coords, 8, 16, placement="morton"))
    rand = plan_stats(
        make_spgemm_plan(a.coords, a.coords, 8, 16, placement="random")
    )
    ag = plan_stats(
        make_spgemm_plan(a.coords, a.coords, 8, 16, exchange="allgather")
    )
    assert morton["recv_bytes_mean"] < 0.5 * rand["recv_bytes_mean"]
    assert morton["recv_bytes_mean"] < 0.25 * ag["recv_bytes_mean"]


def test_banded_touches_few_ring_offsets():
    # Morton partition of a band: only neighbouring partitions exchange
    a = banded_matrix(512, 8, 16, seed=5)
    plan = make_spgemm_plan(a.coords, a.coords, 8, 16)
    assert len(plan.a_offsets) + len(plan.b_offsets) <= 8


def test_partition_morton_weighted():
    w = np.array([10.0, 1, 1, 1, 1, 1, 1, 10])
    owner = partition_morton(8, 2, w)
    loads = [w[owner == p].sum() for p in range(2)]
    assert max(loads) / (sum(loads) / 2) < 1.5


def test_partition_random_covers():
    owner = partition_random(100, 7, seed=3)
    assert set(owner.tolist()) == set(range(7))


def test_partition_morton_snaps_to_subtree_boundaries():
    # dense power-of-two grid: every partition cut can land on a node start
    n, bs, nparts = 64, 8, 4
    a = random_block_matrix(n, bs, 1.0, 0)
    align = subtree_boundaries(a.coords)
    owner = partition_morton(a.nnzb, nparts, align=align)
    cuts = np.nonzero(np.diff(owner))[0] + 1
    assert np.all(np.isin(cuts, align))
    # balance is preserved within the slack
    loads = np.bincount(owner, minlength=nparts)
    assert loads.max() / (a.nnzb / nparts) < 1.3


def test_partition_morton_alignment_respects_balance_slack():
    # pathological weights: snapping must not blow the balance bound
    rng = np.random.default_rng(1)
    w = rng.random(128) * 10
    align = np.array([0, 1, 127, 128])  # useless candidates far from targets
    owner = partition_morton(128, 4, w, align=align)
    loads = np.array([w[owner == p].sum() for p in range(4)])
    assert loads.max() / (w.sum() / 4) < 1.5  # cuts stayed near the quantiles


def test_subtree_boundaries_unsorted_returns_none():
    coords = np.array([[3, 3], [0, 0]])  # not Morton order
    assert subtree_boundaries(coords) is None
    assert subtree_boundaries(np.zeros((0, 2), dtype=np.int64)) is None


def test_aligned_plan_keeps_locality_and_balance():
    a = banded_matrix(512, 20, 16, seed=4)
    aligned = plan_stats(make_spgemm_plan(a.coords, a.coords, 8, 16))
    unaligned = plan_stats(
        make_spgemm_plan(a.coords, a.coords, 8, 16, align_subtrees=False)
    )
    assert aligned["task_balance"] < 1.6
    # subtree alignment must not cost communication (same or fewer bytes)
    assert aligned["recv_bytes_mean"] <= unaligned["recv_bytes_mean"] * 1.1
