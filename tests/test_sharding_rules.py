import subprocess
import sys
import os

import numpy as np
import pytest
from jax.sharding import PartitionSpec

import jax
from repro.configs import get_config
from repro.models import transformer
from repro.sharding.rules import DEFAULT_RULES, MeshCtx, logical_to_spec, spec_tree


class _Ctx:
    """Duck-typed ctx with arbitrary axis sizes (no devices needed)."""

    def __init__(self, sizes, rules=None):
        self._sizes = sizes
        self.rule_map = dict(DEFAULT_RULES)
        if rules:
            self.rule_map.update(rules)

    @property
    def axis_sizes(self):
        return self._sizes


def test_divisible_dims_shard():
    ctx = _Ctx({"data": 16, "model": 16})
    spec = logical_to_spec(ctx, (8192, 29568), ("embed", "mlp"))
    assert spec == PartitionSpec("data", "model")


def test_non_divisible_dims_replicate():
    ctx = _Ctx({"data": 16, "model": 16})
    # qwen2-0.5b attention: 14 heads on a 16-way model axis -> replicated
    # (3D weights expose the head count to the rules)
    spec = logical_to_spec(ctx, (896, 14, 64), ("embed", "heads", None))
    assert spec == PartitionSpec("data", None, None)
    # qwen2-72b: 64 heads shard cleanly
    spec = logical_to_spec(ctx, (8192, 64, 128), ("embed", "heads", None))
    assert spec == PartitionSpec("data", "model", None)
    # vocab 504 (hubert) not divisible -> replicated
    spec = logical_to_spec(ctx, (1280, 504), ("embed", "vocab"))
    assert spec == PartitionSpec("data", None)


def test_axes_used_once():
    ctx = _Ctx({"data": 16, "model": 16})
    # both dims map to model: only the first gets it
    spec = logical_to_spec(ctx, (64, 128), ("heads", "mlp"))
    assert spec == PartitionSpec("model", None)


def test_multi_axis_batch():
    ctx = _Ctx({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(ctx, (256, 4096), ("batch", "seq"))
    assert spec == PartitionSpec(("pod", "data"), None)
    # batch=1 (long_500k): falls back to replicated
    spec = logical_to_spec(ctx, (1, 4096), ("batch", "seq"))
    assert spec == PartitionSpec(None, None)


def test_spec_tree_covers_all_arch_params():
    ctx = _Ctx({"data": 16, "model": 16})
    for arch in ["qwen2-72b", "kimi-k2-1t-a32b", "mamba2-370m", "recurrentgemma-9b"]:
        cfg = get_config(arch)
        params, axes = transformer.abstract_params(cfg)
        specs = spec_tree(ctx, params, axes)
        n_params = len(jax.tree.leaves(params))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec)))
        assert n_params == n_specs
        # every big tensor (>=8M elements) must be sharded on at least one axis
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        for p, s in zip(flat_p, flat_s):
            if int(np.prod(p.shape)) >= (1 << 23):
                assert any(e is not None for e in s), (p.shape, s)
