import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BSMatrix,
    LeafSpec,
    SymbolicCache,
    exact_spgemm_flops,
    multiply,
    spamm,
    spamm_symbolic,
    spgemm_symbolic,
    spgemm_symbolic_recursive,
    spgemm_symbolic_tree,
    symm_square,
    syrk,
    task_flops,
)
from repro.core.spgemm import _common_depth

from helpers import banded_matrix, random_block_matrix


def decay_matrix(n: int, bs: int, rate: float = 0.5, seed: int = 0) -> BSMatrix:
    """Exponential off-diagonal decay — the paper's SpAMM-friendly sequence."""
    rng = np.random.default_rng(seed)
    i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    a = rng.standard_normal((n, n)).astype(np.float32) * np.exp(
        -rate * np.abs(i - j)
    ).astype(np.float32)
    return BSMatrix.from_dense(a, bs)


@given(
    n=st.integers(8, 70),
    bs=st.sampled_from([4, 8, 16]),
    da=st.floats(0.05, 0.9),
    db=st.floats(0.05, 0.9),
    seed=st.integers(0, 5),
)
@settings(max_examples=25, deadline=None)
def test_multiply_matches_dense(n, bs, da, db, seed):
    a = random_block_matrix(n, bs, da, seed)
    b = random_block_matrix(n, bs, db, seed + 100)
    c = multiply(a, b)
    ref = a.to_dense() @ b.to_dense()
    assert np.allclose(c.to_dense(), ref, atol=1e-3 * max(1, np.abs(ref).max()))


def test_multiply_rectangular():
    rng = np.random.default_rng(0)
    a = BSMatrix.from_dense(rng.standard_normal((24, 40)).astype(np.float32), 8)
    b = BSMatrix.from_dense(rng.standard_normal((40, 16)).astype(np.float32), 8)
    c = multiply(a, b)
    assert c.shape == (24, 16)
    assert np.allclose(c.to_dense(), a.to_dense() @ b.to_dense(), atol=1e-4)


@given(n=st.integers(8, 48), bs=st.sampled_from([4, 8]), seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_symbolic_recursive_equals_flat(n, bs, seed):
    a = random_block_matrix(n, bs, 0.3, seed)
    b = random_block_matrix(n, bs, 0.3, seed + 7)
    t1 = spgemm_symbolic(a.coords, b.coords)
    t2 = spgemm_symbolic_recursive(a.coords, b.coords)
    k1 = set(zip(t1.a_idx.tolist(), t1.b_idx.tolist()))
    k2 = set(zip(t2.a_idx.tolist(), t2.b_idx.tolist()))
    assert k1 == k2
    assert np.array_equal(t1.c_coords, t2.c_coords)


def test_zero_branches_pruned():
    # banded x banded: far-off-diagonal output blocks must not even appear
    a = banded_matrix(128, 3, 8)
    t = spgemm_symbolic(a.coords, a.coords)
    i, j = t.c_coords[:, 0], t.c_coords[:, 1]
    assert np.all(np.abs(i - j) <= 2)  # band of blocks only
    nb = a.nblocks[0]
    assert t.num_out < nb * nb / 2


def test_syrk():
    a = banded_matrix(80, 5, 8, seed=3)
    s = syrk(a)
    ref = a.to_dense() @ a.to_dense().T
    assert np.allclose(s.to_dense(), ref, atol=1e-4)
    # result is exactly symmetric in structure
    codes = {tuple(x) for x in s.coords.tolist()}
    assert all((j, i) in codes for i, j in codes)


@given(tau=st.floats(0.01, 50.0), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_spamm_error_bound(tau, seed):
    a = banded_matrix(64, 4, 8, seed)
    b = banded_matrix(64, 4, 8, seed + 1)
    c, bound = spamm(a, b, tau)
    err = np.linalg.norm(c.to_dense() - a.to_dense() @ b.to_dense())
    assert bound <= tau + 1e-9
    assert err <= bound + 1e-3  # float32 numeric slack


def test_spamm_skips_work():
    a = banded_matrix(128, 10, 8)
    full = spgemm_symbolic(a.coords, a.coords).num_tasks
    # large tau should prune tasks
    c, bound = spamm(a, a, tau=a.frobenius_norm())
    kept = spgemm_symbolic(a.coords, a.coords)  # recompute full for comparison
    assert c.nnzb <= kept.num_out


def test_flop_counting():
    a = banded_matrix(64, 5, 16)
    t = spgemm_symbolic(a.coords, a.coords)
    dense_flops = task_flops(t, 16)
    exact = exact_spgemm_flops(a, a, t, LeafSpec("block_sparse", inner_bs=4))
    assert 0 < exact <= dense_flops
    # dense leaf counting equals task_flops
    assert exact_spgemm_flops(a, a, t, LeafSpec("dense")) == dense_flops


def test_symm_square():
    a = banded_matrix(64, 5, 8, seed=11)
    sym = BSMatrix.from_dense(a.to_dense() + a.to_dense().T, 8)
    sq = symm_square(sym)
    ref = sym.to_dense() @ sym.to_dense()
    assert np.allclose(sq.to_dense(), ref, atol=1e-4)


# -- vectorized quadtree descent (production symbolic path) ------------------


@given(
    n=st.integers(8, 64),
    bs=st.sampled_from([4, 8]),
    da=st.floats(0.05, 1.0),
    db=st.floats(0.05, 1.0),
    seed=st.integers(0, 8),
)
@settings(max_examples=25, deadline=None)
def test_symbolic_tree_identical_to_flat(n, bs, da, db, seed):
    a = random_block_matrix(n, bs, da, seed)
    b = random_block_matrix(n, bs, db, seed + 31)
    t1 = spgemm_symbolic(a.coords, b.coords)
    depth = _common_depth(a, b)
    t2 = spgemm_symbolic_tree(a.quadtree_index(depth), b.quadtree_index(depth))
    # bit-identical Tasks, not just the same set
    assert np.array_equal(t1.a_idx, t2.a_idx)
    assert np.array_equal(t1.b_idx, t2.b_idx)
    assert np.array_equal(t1.c_idx, t2.c_idx)
    assert np.array_equal(t1.c_coords, t2.c_coords)


def test_symbolic_tree_rectangular():
    rng = np.random.default_rng(5)
    a = BSMatrix.from_dense(rng.standard_normal((24, 72)).astype(np.float32), 8)
    b = BSMatrix.from_dense(rng.standard_normal((72, 16)).astype(np.float32), 8)
    t1 = spgemm_symbolic(a.coords, b.coords)
    depth = _common_depth(a, b)
    t2 = spgemm_symbolic_tree(a.quadtree_index(depth), b.quadtree_index(depth))
    assert np.array_equal(t1.a_idx, t2.a_idx)
    assert np.array_equal(t1.c_coords, t2.c_coords)
    c = multiply(a, b)  # production path goes through the descent
    assert np.allclose(c.to_dense(), a.to_dense() @ b.to_dense(), atol=1e-3)


def test_multiply_symbolic_cache():
    cache = SymbolicCache()
    a = random_block_matrix(48, 8, 0.4, 1)
    b = random_block_matrix(48, 8, 0.4, 2)
    c1 = multiply(a, b, cache=cache)
    c2 = multiply(a, b, cache=cache)  # second call skips the symbolic phase
    assert cache.hits == 1 and cache.misses == 1
    assert np.array_equal(np.asarray(c1.data), np.asarray(c2.data))
    # uncached result is bit-identical
    c3 = multiply(a, b)
    assert np.array_equal(c1.coords, c3.coords)
    assert np.array_equal(np.asarray(c1.data), np.asarray(c3.data))


# -- hierarchical SpAMM ------------------------------------------------------


@given(tau=st.floats(0.01, 50.0), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_spamm_hierarchical_error_bound(tau, seed):
    a = decay_matrix(64, 8, rate=0.3, seed=seed)
    b = decay_matrix(64, 8, rate=0.3, seed=seed + 1)
    c, bound = spamm(a, b, tau)
    err = np.linalg.norm(c.to_dense() - a.to_dense() @ b.to_dense())
    assert bound <= tau + 1e-9
    assert err <= bound + 1e-3  # float32 numeric slack


def test_spamm_hierarchical_visits_fewer_nodes():
    # decay sequence: pruning during descent must skip whole subtrees, so the
    # symbolic phase visits strictly fewer node pairs than full enumeration
    a = decay_matrix(256, 8, rate=0.15, seed=3)
    depth = _common_depth(a, a)
    ia = a.quadtree_index(depth)
    full_tasks, _, full_visits = spamm_symbolic(ia, ia, 0.0)
    tau = 1e-2 * a.frobenius_norm() ** 2
    tasks, err, visits = spamm_symbolic(ia, ia, tau)
    assert visits < full_visits, (visits, full_visits)
    assert tasks.num_tasks < full_tasks.num_tasks
    assert err <= tau


def test_spamm_leaf_method_still_available():
    a = banded_matrix(64, 4, 8, 1)
    c_h, e_h = spamm(a, a, 1.0)
    c_l, e_l = spamm(a, a, 1.0, method="leaf")
    ref = a.to_dense() @ a.to_dense()
    for c, e in [(c_h, e_h), (c_l, e_l)]:
        assert e <= 1.0 + 1e-9
        assert np.linalg.norm(c.to_dense() - ref) <= e + 1e-3


# -- symmetric hierarchy descent (syrk / symm_square) ------------------------


def _upper_filter_flat(a, at):
    """The old enumerate-then-filter symbolic path for C = A @ A^T, kept as
    the reference the upper_only descent must reproduce bit-for-bit."""
    from repro.core.spgemm import Tasks

    tasks = spgemm_symbolic(a.coords, at.coords)
    keep = tasks.c_coords[tasks.c_idx, 0] <= tasks.c_coords[tasks.c_idx, 1]
    kept_out = np.unique(tasks.c_idx[keep])
    remap = -np.ones(tasks.num_out, dtype=np.int64)
    remap[kept_out] = np.arange(kept_out.size)
    return Tasks(
        a_idx=tasks.a_idx[keep],
        b_idx=tasks.b_idx[keep],
        c_idx=remap[tasks.c_idx[keep]],
        c_coords=tasks.c_coords[kept_out],
    )


@given(n=st.integers(8, 64), bs=st.sampled_from([4, 8]), seed=st.integers(0, 8))
@settings(max_examples=20, deadline=None)
def test_symmetric_descent_bit_identical_to_flat_filter(n, bs, seed):
    a = random_block_matrix(n, bs, 0.4, seed)
    at = a.transpose()
    ref = _upper_filter_flat(a, at)
    depth = _common_depth(a, at)
    tree = spgemm_symbolic_tree(
        a.quadtree_index(depth), at.quadtree_index(depth), upper_only=True
    )
    assert np.array_equal(ref.a_idx, tree.a_idx)
    assert np.array_equal(ref.b_idx, tree.b_idx)
    assert np.array_equal(ref.c_idx, tree.c_idx)
    assert np.array_equal(ref.c_coords, tree.c_coords)


def test_symmetric_descent_halves_visits():
    from repro.core.spgemm import _tree_descend

    a = random_block_matrix(128, 8, 0.6, seed=2)
    at = a.transpose()
    depth = _common_depth(a, at)
    ia, ib = a.quadtree_index(depth), at.quadtree_index(depth)
    _, _, _, v_full = _tree_descend(ia, ib, tau=None)
    _, _, _, v_upper = _tree_descend(ia, ib, tau=None, upper_only=True)
    # strictly-lower subtrees are dropped mid-descent: the symmetric
    # descent visits roughly half the node pairs of the full one
    assert v_upper < 0.65 * v_full, (v_upper, v_full)


# -- satellite: syrk / symm_square / truncate_elementwise edge cases ---------


@pytest.mark.parametrize("n,bs", [(40, 16), (56, 8), (24, 16)])
def test_syrk_non_power_of_two_grid(n, bs):
    # non-power-of-two block grids (5x5, 7x7, ...) against the dense reference
    a = random_block_matrix(n, bs, 0.5, seed=n)
    s = syrk(a)
    assert np.allclose(s.to_dense(), a.to_dense() @ a.to_dense().T, atol=1e-4)


@pytest.mark.parametrize("n,bs", [(40, 8), (48, 16)])
def test_symm_square_non_power_of_two_grid(n, bs):
    a = random_block_matrix(n, bs, 0.4, seed=n + 1)
    sym = BSMatrix.from_dense(a.to_dense() + a.to_dense().T, bs)
    assert np.allclose(
        symm_square(sym).to_dense(), sym.to_dense() @ sym.to_dense(), atol=1e-4
    )


def test_syrk_empty():
    z = BSMatrix.zeros((40, 24), 8)
    s = syrk(z)
    assert s.shape == (40, 40) and s.nnzb == 0
    assert np.allclose(s.to_dense(), 0.0)


# -- satellite: leaf-level inner sparsity tightens SpAMM bounds --------------


def _inner_strip_matrix(n, bs, kind, seed=0):
    """Leaves with one nonzero inner half: 'cols' keeps the left inner
    column strip [:, :bs//2], 'rows' keeps the bottom inner row strip."""
    rng = np.random.default_rng(seed)
    nb = n // bs
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(nb):
        for j in range(nb):
            blk = np.zeros((bs, bs), np.float32)
            if kind == "cols":
                blk[:, : bs // 2] = rng.standard_normal((bs, bs // 2))
            else:
                blk[bs // 2 :, :] = rng.standard_normal((bs // 2, bs))
            a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = blk
    return BSMatrix.from_dense(a, bs)


def test_spamm_dense_leaf_spec_bit_identical():
    # kind="dense": the inner block IS the leaf, the refined bound
    # degenerates to the descent's own — results must match today's exactly
    from repro.core.leaf import LeafSpec

    a = banded_matrix(96, 10, 16, seed=3)
    for tau in (0.0, 1e-1, 5.0):
        c0, e0 = spamm(a, a, tau)
        c1, e1 = spamm(a, a, tau, leaf_spec=LeafSpec("dense"))
        assert e0 == e1
        assert np.array_equal(c0.coords, c1.coords)
        assert np.array_equal(np.asarray(c0.data), np.asarray(c1.data))


def test_spamm_inner_sparsity_prunes_disjoint_leaves():
    # A's leaves live in the left inner column strip, B's in the bottom inner
    # row strip: every leaf product is exactly zero (A[:, :h] @ B_zero_top),
    # the inner-norm bound ||Na @ Nb||_F sees it and prunes every task for
    # free, while the flat leaf bound ||A|| * ||B|| keeps them all
    from repro.core.leaf import LeafSpec

    n, bs = 64, 16
    a = _inner_strip_matrix(n, bs, "cols", seed=1)
    b = _inner_strip_matrix(n, bs, "rows", seed=2)
    tau = 1e-3
    c_plain, e_plain = spamm(a, b, tau)
    spec = LeafSpec("block_sparse", inner_bs=bs // 2)
    c_inner, e_inner = spamm(a, b, tau, leaf_spec=spec)
    assert c_plain.nnzb > 0  # flat bound keeps the (numerically zero) tasks
    assert c_inner.nnzb == 0  # inner bound proves them all zero
    assert e_inner <= tau + 1e-12
    # the products really are zero: pruning them costs no error at all
    ref = np.asarray(a.to_dense(), np.float64) @ np.asarray(b.to_dense(), np.float64)
    assert np.abs(ref).max() < 1e-4


def test_spamm_inner_sparsity_error_bound_holds():
    from repro.core.leaf import LeafSpec

    rng = np.random.default_rng(9)
    n, bs = 64, 16
    dense = rng.standard_normal((n, n)).astype(np.float32)
    dense[np.abs(dense) < 1.2] = 0.0  # sparse inside leaves
    a = BSMatrix.from_dense(dense, bs)
    spec = LeafSpec("block_sparse", inner_bs=8)
    for tau in (1e-2, 1.0, 10.0):
        c_plain, e_plain = spamm(a, a, tau)
        c_inner, e_inner = spamm(a, a, tau, leaf_spec=spec)
        assert e_inner <= tau + 1e-9
        # tighter bounds can only prune more, never less
        assert c_inner.nnzb <= c_plain.nnzb
        ref = np.asarray(a.to_dense(), np.float64) @ np.asarray(a.to_dense(), np.float64)
        err = float(np.linalg.norm(np.asarray(c_inner.to_dense(), np.float64) - ref))
        assert err <= e_inner + 1e-2


def test_block_frobenius_norms_inner_layout():
    from repro.core.matrix import block_frobenius_norms

    rng = np.random.default_rng(4)
    d = rng.standard_normal((3, 16, 16)).astype(np.float32)
    flat = np.asarray(block_frobenius_norms(d))
    inner = np.asarray(block_frobenius_norms(d, inner=8))
    assert inner.shape == (3, 2, 2)
    # inner squares sum back to the leaf square, and the layout is
    # (row tile, col tile): zeroing the right half kills column tile 1
    assert np.allclose(np.sqrt((inner.astype(np.float64) ** 2).sum(axis=(1, 2))), flat, rtol=1e-5)
    d2 = d.copy()
    d2[:, :, 8:] = 0
    inner2 = np.asarray(block_frobenius_norms(d2, inner=8))
    assert np.all(inner2[:, :, 1] == 0) and np.all(inner2[:, :, 0] > 0)
