import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BSMatrix,
    LeafSpec,
    exact_spgemm_flops,
    multiply,
    spamm,
    spgemm_symbolic,
    spgemm_symbolic_recursive,
    syrk,
    task_flops,
)

from helpers import banded_matrix, random_block_matrix


@given(
    n=st.integers(8, 70),
    bs=st.sampled_from([4, 8, 16]),
    da=st.floats(0.05, 0.9),
    db=st.floats(0.05, 0.9),
    seed=st.integers(0, 5),
)
@settings(max_examples=25, deadline=None)
def test_multiply_matches_dense(n, bs, da, db, seed):
    a = random_block_matrix(n, bs, da, seed)
    b = random_block_matrix(n, bs, db, seed + 100)
    c = multiply(a, b)
    ref = a.to_dense() @ b.to_dense()
    assert np.allclose(c.to_dense(), ref, atol=1e-3 * max(1, np.abs(ref).max()))


def test_multiply_rectangular():
    rng = np.random.default_rng(0)
    a = BSMatrix.from_dense(rng.standard_normal((24, 40)).astype(np.float32), 8)
    b = BSMatrix.from_dense(rng.standard_normal((40, 16)).astype(np.float32), 8)
    c = multiply(a, b)
    assert c.shape == (24, 16)
    assert np.allclose(c.to_dense(), a.to_dense() @ b.to_dense(), atol=1e-4)


@given(n=st.integers(8, 48), bs=st.sampled_from([4, 8]), seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_symbolic_recursive_equals_flat(n, bs, seed):
    a = random_block_matrix(n, bs, 0.3, seed)
    b = random_block_matrix(n, bs, 0.3, seed + 7)
    t1 = spgemm_symbolic(a.coords, b.coords)
    t2 = spgemm_symbolic_recursive(a.coords, b.coords)
    k1 = set(zip(t1.a_idx.tolist(), t1.b_idx.tolist()))
    k2 = set(zip(t2.a_idx.tolist(), t2.b_idx.tolist()))
    assert k1 == k2
    assert np.array_equal(t1.c_coords, t2.c_coords)


def test_zero_branches_pruned():
    # banded x banded: far-off-diagonal output blocks must not even appear
    a = banded_matrix(128, 3, 8)
    t = spgemm_symbolic(a.coords, a.coords)
    i, j = t.c_coords[:, 0], t.c_coords[:, 1]
    assert np.all(np.abs(i - j) <= 2)  # band of blocks only
    nb = a.nblocks[0]
    assert t.num_out < nb * nb / 2


def test_syrk():
    a = banded_matrix(80, 5, 8, seed=3)
    s = syrk(a)
    ref = a.to_dense() @ a.to_dense().T
    assert np.allclose(s.to_dense(), ref, atol=1e-4)
    # result is exactly symmetric in structure
    codes = {tuple(x) for x in s.coords.tolist()}
    assert all((j, i) in codes for i, j in codes)


@given(tau=st.floats(0.01, 50.0), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_spamm_error_bound(tau, seed):
    a = banded_matrix(64, 4, 8, seed)
    b = banded_matrix(64, 4, 8, seed + 1)
    c, bound = spamm(a, b, tau)
    err = np.linalg.norm(c.to_dense() - a.to_dense() @ b.to_dense())
    assert bound <= tau + 1e-9
    assert err <= bound + 1e-3  # float32 numeric slack


def test_spamm_skips_work():
    a = banded_matrix(128, 10, 8)
    full = spgemm_symbolic(a.coords, a.coords).num_tasks
    # large tau should prune tasks
    c, bound = spamm(a, a, tau=a.frobenius_norm())
    kept = spgemm_symbolic(a.coords, a.coords)  # recompute full for comparison
    assert c.nnzb <= kept.num_out


def test_flop_counting():
    a = banded_matrix(64, 5, 16)
    t = spgemm_symbolic(a.coords, a.coords)
    dense_flops = task_flops(t, 16)
    exact = exact_spgemm_flops(a, a, t, LeafSpec("block_sparse", inner_bs=4))
    assert 0 < exact <= dense_flops
    # dense leaf counting equals task_flops
    assert exact_spgemm_flops(a, a, t, LeafSpec("dense")) == dense_flops


def test_symm_square():
    from repro.core import symm_square

    a = banded_matrix(64, 5, 8, seed=11)
    sym = BSMatrix.from_dense(a.to_dense() + a.to_dense().T, 8)
    sq = symm_square(sym)
    ref = sym.to_dense() @ sym.to_dense()
    assert np.allclose(sq.to_dense(), ref, atol=1e-4)
