"""Recurrent blocks: chunked/parallel forms vs naive sequential recurrences,
and decode steps vs the parallel form (cache-correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru as rec
from repro.models import ssd


def test_ssd_chunked_matches_sequential():
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 32, 3, 4, 8
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, H)) * 0.5 + 0.1, jnp.float32)
    a_log = jnp.asarray(np.log(rng.random(H) * 2 + 0.5), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)

    y, final = ssd.ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=8)

    # naive recurrence
    A = -np.exp(np.asarray(a_log))
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dec = np.exp(np.asarray(dt)[:, t] * A)  # [B, H]
        upd = np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt)[:, t], np.asarray(xh)[:, t], np.asarray(Bm)[:, t]
        )
        h = h * dec[..., None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(Cm)[:, t]))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), h, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_size_invariance():
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 64, 2, 4, 8
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, H)) * 0.3 + 0.1, jnp.float32)
    a_log = jnp.asarray(np.zeros(H), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y8, _ = ssd.ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=8)
    y16, _ = ssd.ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=16)
    y64, _ = ssd.ssd_chunked(xh, dt, a_log, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=1e-4, atol=1e-4)


def test_ssd_block_decode_matches_apply():
    key = jax.random.key(0)
    d, d_inner, heads, d_state = 16, 32, 4, 8
    p, _ = ssd.ssd_block_init(key, d, d_inner=d_inner, heads=heads, d_state=d_state)
    rng = np.random.default_rng(2)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    y_par = ssd.ssd_block_apply(p, x, d_inner=d_inner, heads=heads, d_state=d_state, chunk=4)
    state = ssd.ssd_init_state(B, d_inner=d_inner, heads=heads, d_state=d_state)
    outs = []
    for t in range(S):
        y, state = ssd.ssd_decode_step(
            p, x[:, t : t + 1], state, d_inner=d_inner, heads=heads, d_state=d_state
        )
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-3, atol=1e-3)


def test_rglru_scan_matches_sequential():
    key = jax.random.key(1)
    d, heads = 16, 4
    p, _ = rec.rglru_block_init(key, d, heads)
    rng = np.random.default_rng(3)
    B, S = 2, 20
    u = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    y, h_last = rec._rglru_scan(p, u, heads)
    # sequential
    r, i = rec._gates(p, u, heads)
    a = np.exp(
        -rec._C * np.asarray(jax.nn.softplus(p["lam"])) * np.asarray(r, np.float64)
    )
    g = np.sqrt(np.maximum(1 - a**2, 1e-12)) * (np.asarray(i) * np.asarray(u))
    h = np.zeros((B, d))
    ys = []
    for t in range(S):
        h = a[:, t] * h + g[:, t]
        ys.append(h.copy())
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-4, atol=1e-4)


def test_rglru_block_decode_matches_apply():
    key = jax.random.key(2)
    d, heads = 16, 4
    p, _ = rec.rglru_block_init(key, d, heads)
    rng = np.random.default_rng(4)
    B, S = 2, 10
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    y_par = rec.rglru_block_apply(p, x, heads=heads)
    state = rec.rglru_init_state(B, d)
    outs = []
    for t in range(S):
        y, state = rec.rglru_decode_step(p, x[:, t : t + 1], state, heads=heads)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-3, atol=1e-3)
