"""End-to-end behaviour tests for the paper's system.

The paper's workflow: construct distributed sparse matrices, multiply with
dynamic locality-aware scheduling, apply to electronic-structure kernels
(inverse factorization, purification).  These tests run the whole stack —
symbolic quadtree phase, schedule, numeric phase, truncation — against
dense oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BSMatrix,
    factorization_residual,
    inv_chol,
    multiply,
    sp2_purify,
    truncate,
)
from repro.core.schedule import make_spgemm_plan, plan_stats

from helpers import banded_matrix


def test_weak_scaling_families_end_to_end():
    """The paper's three test families, full pipeline."""
    rng = np.random.default_rng(0)
    n, bs, hw = 512, 32, 48

    def banded():
        a = np.zeros((n, n), dtype=np.float32)
        for i in range(n):
            lo, hi = max(0, i - hw), min(n, i + hw + 1)
            a[i, lo:hi] = rng.standard_normal(hi - lo)
        return a

    fams = {"banded": banded()}
    g = banded()
    g[: n // 4, : n // 4] = rng.standard_normal((n // 4, n // 4))
    fams["growing"] = g
    r = banded()
    s = n // 8
    for st in (0, n // 2):
        r[st : st + s, st : st + s] = rng.standard_normal((s, s))
    fams["random"] = r

    for name, dense in fams.items():
        a = BSMatrix.from_dense(dense, bs)
        c = multiply(a, a)
        assert np.allclose(c.to_dense(), dense @ dense, atol=1e-2), name
        plan = make_spgemm_plan(a.coords, a.coords, 4, bs)
        st = plan_stats(plan)
        assert st["task_balance"] < 2.0, (name, st)


def test_electronic_structure_pipeline():
    """inv-factorize overlap, transform, purify — the paper's app domain."""
    rng = np.random.default_rng(3)
    n, bs, nocc = 128, 16, 40
    h = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - 3), min(n, i + 4)
        h[i, lo:hi] = 0.2 * rng.standard_normal(hi - lo)
    h = (h + h.T) / 2 + np.diag(np.linspace(-1, 1, n))
    f = BSMatrix.from_dense(h, bs)
    s = BSMatrix.from_dense(np.eye(n, dtype=np.float32) + 0.01 * np.abs(h), bs)
    z = inv_chol(s)
    assert factorization_residual(s, z) < 1e-4
    f_o = multiply(multiply(z.transpose(), f), z)
    w = np.linalg.eigvalsh(np.asarray(f_o.to_dense(), np.float64))
    d, stats = sp2_purify(
        f_o, nocc, float(w.min()) - 0.05, float(w.max()) + 0.05, idem_tol=1e-5, trunc_tau=1e-5
    )
    assert abs(d.trace() - nocc) < 0.05
    x2 = multiply(d, d)
    assert np.abs(x2.to_dense() - d.to_dense()).max() < 1e-2  # idempotent


def test_truncated_multiply_chain_error_accumulation():
    """Chained multiply+truncate keeps controlled total error (library use)."""
    a = banded_matrix(256, 8, 16, seed=9)
    a = a.scale(1.0 / np.linalg.norm(a.to_dense(), 2))
    exact = a.to_dense().astype(np.float64)
    approx = a
    tau = 1e-4
    for _ in range(3):
        exact = exact @ exact
        approx = truncate(multiply(approx, approx), tau)
    err = np.linalg.norm(approx.to_dense() - exact)
    assert err < 50 * tau


def test_quadtree_sparsity_survives_squaring():
    a = banded_matrix(512, 4, 16)
    c = multiply(a, a)
    nb = a.nblocks[0]
    assert c.nnzb < 0.2 * nb * nb  # banded^2 is still banded (width doubles)


def test_purify_symbolic_cache_hits_and_bit_identical():
    """Stable-pattern SP2 iterations skip the symbolic phase via the
    structure-keyed SymbolicCache, with results bit-identical to uncached."""
    from repro.core import SymbolicCache

    rng = np.random.default_rng(3)
    n, bs, nocc = 128, 16, 40
    h = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - 3), min(n, i + 4)
        h[i, lo:hi] = 0.2 * rng.standard_normal(hi - lo)
    h = (h + h.T) / 2 + np.diag(np.linspace(-1, 1, n))
    f = BSMatrix.from_dense(h, bs)
    w = np.linalg.eigvalsh(h.astype(np.float64))
    lmin, lmax = float(w.min()) - 0.05, float(w.max()) + 0.05

    cache = SymbolicCache()
    d1, st1 = sp2_purify(
        f, nocc, lmin, lmax, idem_tol=1e-5, trunc_tau=1e-5, impl="ref", cache=cache
    )
    assert st1.symbolic_cache["hits"] > 0
    assert st1.symbolic_cache["hits"] + st1.symbolic_cache["misses"] == st1.iterations
    # every iteration whose operand structure has been seen before is a hit;
    # only structure-changing iterations (truncation altered the pattern) miss
    hits = np.asarray(st1.cache_hits_history)
    assert ((hits == 0) | (hits == 1)).all()
    # once the pattern stabilizes the tail is all hits
    assert hits[-3:].tolist() == [1, 1, 1]

    # bit-identical to the uncached (fresh-cache) run
    d2, _ = sp2_purify(f, nocc, lmin, lmax, idem_tol=1e-5, trunc_tau=1e-5, impl="ref")
    assert np.array_equal(d1.coords, d2.coords)
    assert np.array_equal(np.asarray(d1.data), np.asarray(d2.data))

    # a second solve sharing the cache starts hot: zero misses
    m0 = cache.misses
    d3, st3 = sp2_purify(
        f, nocc, lmin, lmax, idem_tol=1e-5, trunc_tau=1e-5, impl="ref", cache=cache
    )
    assert cache.misses == m0
    assert np.array_equal(np.asarray(d1.data), np.asarray(d3.data))
